//! Per-job protocol state machine: FediAC's two phases over real payloads,
//! with register-window wave accounting.
//!
//! One `Job` owns everything a tenant needs: the agreed [`JobSpec`], a
//! byte-accounted [`RegisterFile`] sized by the switch's [`PsProfile`], the
//! client address book, and a small window of per-round states. Each round
//! runs:
//!
//! 1. **vote phase** — packed bitmap blocks accumulate into u16 counters
//!    through [`VoteAggregator`] waves; when every block is complete the
//!    counters are thresholded ([`alu::threshold_votes`]) into the GIA,
//!    Golomb-coded and multicast;
//! 2. **update phase** — aligned i32 lanes accumulate through
//!    [`UpdateAggregator`] waves; the finished aggregate is multicast.
//!
//! *Waves*: only `window` blocks of registers are resident at a time
//! (`window_blocks` of the profile's memory). Packets beyond the window
//! spill to host memory and are drained as waves retire — the operational
//! form of §III-B's "process the index space in waves" behaviour. The
//! per-wave [`crate::switch::Scoreboard`] (inside the aggregators) drops
//! retransmitted duplicates so lossy links never double-count.
//!
//! **Sans-I/O.** A `Job` owns no socket and never reads a clock: every
//! input arrives through [`Job::handle`] (one decoded frame plus the
//! caller's `now`) or [`Job::on_tick`] (a timer deadline arriving), and
//! every effect comes back as a [`JobOutput`] — datagrams to transmit
//! and the next deadline to call `on_tick` at. The threaded and reactor
//! backends ([`crate::server::daemon`]) are thin drivers over this state
//! machine, which is also why it is testable without sockets
//! (`tests/job_machine.rs`) and why both backends are bit-exact with
//! each other by construction.

use std::collections::{BTreeMap, HashMap};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::golomb;
use crate::configx::PsProfile;
use crate::server::{HostBudget, ServerStats};
use crate::switch::{alu, window_blocks, Mark, RegisterFile, UpdateAggregator, VoteAggregator};
use crate::telemetry::{FlightRecorder, TraceNote};
use crate::util::BitVec;
use crate::wire::{
    byte_chunk_bounds, encode_lanes_into, lanes_iter, update_chunk_bounds, Frame, FrameScratch,
    Header, JobSpec, WireKind,
};

/// `JoinAck` status: registered (or re-registered) successfully.
pub const JOIN_OK: u32 = 0;
/// `JoinAck` status: the job exists with a different spec.
pub const JOIN_SPEC_MISMATCH: u32 = 1;
/// `JoinAck` status: a data frame arrived for a job nobody has joined.
pub const JOIN_UNKNOWN_JOB: u32 = 2;
/// `JoinAck` status: the spec is invalid, exceeds this switch's register
/// memory, or exceeds the server's per-job host-memory budget.
pub const JOIN_BAD_SPEC: u32 = 3;

/// Datagrams to transmit in response to one handled input, as
/// `(bytes, destination)` pairs.
pub type Outgoing = Vec<(Vec<u8>, SocketAddr)>;

/// Everything a backend must act on after feeding the job one input:
/// the datagrams to transmit now, and the deadline (if any) at which
/// [`Job::on_tick`] wants to run next. The job never touches a socket
/// or a clock itself — that is the whole sans-I/O contract.
///
/// The frame buffers come from the job's [`FrameScratch`] pool: a
/// backend that hands them back through [`Job::recycle`] after
/// transmitting keeps steady-state emission allocation-free (tracked by
/// `ServerStats::{frames_pooled, pool_misses}`). Not recycling is
/// correct too — it merely re-allocates.
#[derive(Debug, Default)]
pub struct JobOutput {
    /// Datagrams to transmit, in order.
    pub frames: Outgoing,
    /// Earliest pending deadline (idle register reclamation); `None`
    /// when the job is quiescent and needs no wakeup at all.
    pub timer: Option<Instant>,
}

/// Abuse limits for one job — everything an unauthenticated UDP sender
/// could otherwise inflate. Defaults are generous for legitimate jobs;
/// raise `host_bytes` for very large models.
#[derive(Debug, Clone, Copy)]
pub struct JobLimits {
    /// Host bytes one job may pin across its `MAX_LIVE_ROUNDS` live
    /// rounds (vote counters, GIA, update accumulators); a `Join` whose
    /// spec would exceed it is refused with [`JOIN_BAD_SPEC`]. The
    /// daemon-wide worst case is `MAX_JOBS ×` this figure. Enforced
    /// through a [`HostBudget`] accountant; a sharded deployment shares
    /// one accountant across the shard set, so this is the tenant's
    /// budget for the *whole* deployment, not per shard.
    pub host_bytes: usize,
    /// Spilled payload bytes one phase of one round may hold; beyond the
    /// derived entry cap, spill is dropped (and counted) — the client's
    /// retransmission re-delivers once the wave advances.
    pub spill_bytes: usize,
    /// Release an in-progress round's register aggregators after this
    /// long without traffic. The round stays live: retransmission
    /// rebuilds the reclaimed wave, so a stalled or abandoned round
    /// cannot pin the register file forever.
    pub idle_release_after: Duration,
    /// Full GIA/aggregate frame-set re-serves allowed per source address
    /// per round (the completion multicast is not charged). Only `Poll`
    /// triggers a re-serve — late data frames are dropped silently — and
    /// a recovering client spends one unit per timeout cycle, so the
    /// default comfortably exceeds any sane retry policy while bounding
    /// the bytes one small spoofed frame can reflect at a victim.
    pub reserve_budget: u32,
    /// Quorum phase deadline: once a round's phase has been open this
    /// long *and* at least `JobSpec::quorum` clients have delivered
    /// their full phase payload, the phase is force-closed with the
    /// contributions at hand (missing ones count as zero). Armed from
    /// the first data frame of each phase; irrelevant for `quorum = 0`
    /// (legacy all-N) jobs, whose phases only ever close organically.
    pub phase_deadline: Duration,
}

impl Default for JobLimits {
    fn default() -> Self {
        JobLimits {
            host_bytes: 64 << 20,
            spill_bytes: 4 << 20,
            idle_release_after: Duration::from_secs(30),
            reserve_budget: 128,
            phase_deadline: Duration::from_secs(5),
        }
    }
}

/// Spill entry caps derived from `JobLimits::spill_bytes` (the clamp keeps
/// per-entry heap overhead bounded when payloads are tiny).
const MIN_SPILL_ENTRIES: usize = 16;
const MAX_SPILL_ENTRIES: usize = 8192;
/// Distinct source addresses tracked per round for re-serve budgeting.
/// Unregistered sources beyond this (necessarily spoofed floods — real
/// jobs have at most 64 clients) are never re-served; Join-registered
/// addresses bypass the gate so floods cannot lock real clients out.
const MAX_RESERVE_SOURCES: usize = 64;

fn spill_cap(limits: &JobLimits, spec: &JobSpec) -> usize {
    (limits.spill_bytes / (spec.payload_budget.max(1) as usize))
        .clamp(MIN_SPILL_ENTRIES, MAX_SPILL_ENTRIES)
}

/// Sliding register window over a phase's block space.
#[derive(Debug, Clone, Copy)]
struct Wave {
    n_blocks: usize,
    window: usize,
    start: usize,
}

impl Wave {
    fn idle() -> Self {
        Wave { n_blocks: 0, window: 1, start: 0 }
    }

    /// First block past the resident window.
    fn end(&self) -> usize {
        (self.start + self.window).min(self.n_blocks)
    }

    fn done(&self) -> bool {
        self.start >= self.n_blocks
    }
}

/// Phase-1 result kept for (re-)broadcast.
struct GiaReady {
    gia: BitVec,
    encoded: Vec<u8>,
    global_max: f32,
}

/// What became of one ingested data block. Drives both the caller's
/// completion handling and the flight-recorder verdict for the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PacketFate {
    /// Folded into the round state; the phase is still open.
    Accepted,
    /// This packet completed the phase.
    PhaseDone,
    /// Dropped as an already-counted contribution.
    Duplicate,
    /// Dropped for impossible geometry.
    BadFrame,
    /// Parked in the host spill buffer (beyond the register window).
    Spilled,
    /// Dropped because the spill buffer is at its cap.
    SpillDropped,
}

impl PacketFate {
    /// The recorder verdict for this fate (phase completion is reported
    /// per phase by the caller, which knows which phase closed).
    fn note(self, done: TraceNote) -> TraceNote {
        match self {
            PacketFate::Accepted => TraceNote::Accepted,
            PacketFate::PhaseDone => done,
            PacketFate::Duplicate => TraceNote::Duplicate,
            PacketFate::BadFrame => TraceNote::BadFrame,
            PacketFate::Spilled => TraceNote::Spilled,
            PacketFate::SpillDropped => TraceNote::SpillDropped,
        }
    }
}

/// Record one frame verdict into an attached flight recorder. A no-op
/// without a recorder; never allocates either way.
fn trace(
    rec: Option<&FlightRecorder>,
    job: u32,
    h: &Header,
    peer: Option<SocketAddr>,
    note: TraceNote,
    now: Instant,
) {
    if let Some(r) = rec {
        r.note(job, h.round, Some(h.kind), h.client, peer, note, now);
    }
}

/// Completed phase timings of one round, measured purely from the `now`
/// values the caller fed into [`Job::handle`] — the sans-I/O job never
/// reads a clock, so scripted tests control these durations exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RoundTiming {
    /// First data frame of the round → GIA multicast (`None` while
    /// phase 1 is open).
    pub vote: Option<Duration>,
    /// GIA multicast → aggregate multicast (`None` while phase 2 is
    /// open; zero for rounds that close at phase 1 on empty consensus).
    pub update: Option<Duration>,
    /// First data frame → aggregate multicast (`None` until the round
    /// closes).
    pub total: Option<Duration>,
}

/// Per-client distinct-block arrival tally for one phase, driving
/// quorum-based round closure. A client is a *participant* once every
/// block of the phase has been seen from it at least once — spilled
/// blocks count (they drain into the aggregate before any close), while
/// duplicate and capacity-dropped deliveries never do, so the tally is
/// exact under loss, reordering and retransmission.
struct Participation {
    n_blocks: usize,
    /// One `n_blocks`-bit map per client id.
    seen: Vec<BitVec>,
    /// Clients whose map is full.
    complete: u16,
}

impl Participation {
    fn new(n_clients: usize, n_blocks: usize) -> Self {
        Participation {
            n_blocks,
            seen: vec![BitVec::zeros(n_blocks.max(1)); n_clients],
            complete: 0,
        }
    }

    /// Record one validated, newly counted block from `client`.
    fn record(&mut self, client: u16, block: usize) {
        let map = &mut self.seen[client as usize];
        if !map.get(block) {
            map.set(block, true);
            if map.count_ones() == self.n_blocks {
                self.complete += 1;
            }
        }
    }
}

/// One round's aggregation state.
struct RoundState {
    // Phase 1: host-side counter mirror (retired waves land here) plus the
    // resident register wave.
    counters: Vec<u16>,
    vote_wave: Wave,
    vote_agg: Option<VoteAggregator>,
    vote_spill: BTreeMap<(u32, u16), Vec<u8>>,
    local_max: f32,
    gia: Option<GiaReady>,
    // Phase 2 (geometry fixed once the GIA is known).
    upd_acc: Vec<i32>,
    upd_wave: Wave,
    upd_agg: Option<UpdateAggregator>,
    upd_spill: BTreeMap<(u32, u16), Vec<i32>>,
    agg_done: bool,
    /// Per-phase cap on spill entries (derived from `JobLimits`).
    spill_cap: usize,
    /// Full frame-set re-serves already granted per source this round.
    serves: HashMap<SocketAddr, u32>,
    /// Last *validated* data-path packet (idle register reclamation —
    /// garbage or stale-block replays must not count as traffic).
    last_touch: Instant,
    /// When this round's state was created (first data frame observed) —
    /// the zero point for every per-round duration.
    started: Instant,
    /// When phase 1 closed (the GIA multicast moment).
    vote_done_at: Option<Instant>,
    /// Completed phase durations, exported via [`Job::round_timing`].
    timing: RoundTiming,
    /// First register-allocation failure of the current stall, if the
    /// round is stalled; drained into `hist_register_stall` when a wave
    /// next allocates.
    stall_since: Option<Instant>,
    /// Phase-1 per-client participation (quorum close eligibility).
    vote_part: Participation,
    /// Phase-2 participation; geometry set when the GIA fixes `k_S`.
    upd_part: Participation,
    /// First validated `Update` frame of the round — arms the phase-2
    /// quorum deadline (phase 1 arms from `started`).
    upd_started: Option<Instant>,
    /// Retry deadline for a quorum close that stalled on the register
    /// file, so `next_timer` stays monotonic instead of re-returning an
    /// already-elapsed phase deadline every tick.
    close_retry_at: Option<Instant>,
}

impl RoundState {
    fn new(spec: &JobSpec, memory_bytes: usize, spill_cap: usize, now: Instant) -> Self {
        let d = spec.d as usize;
        let n_blocks = spec.vote_n_blocks();
        let window = window_blocks(memory_bytes, spec.vote_block_bits() * 2).min(n_blocks);
        RoundState {
            counters: vec![0u16; d],
            vote_wave: Wave { n_blocks, window, start: 0 },
            vote_agg: None,
            vote_spill: BTreeMap::new(),
            local_max: f32::MIN_POSITIVE,
            gia: None,
            upd_acc: Vec::new(),
            upd_wave: Wave::idle(),
            upd_agg: None,
            upd_spill: BTreeMap::new(),
            agg_done: false,
            spill_cap,
            serves: HashMap::new(),
            last_touch: now,
            started: now,
            vote_done_at: None,
            timing: RoundTiming::default(),
            stall_since: None,
            vote_part: Participation::new(spec.n_clients as usize, n_blocks),
            upd_part: Participation::new(spec.n_clients as usize, 0),
            upd_started: None,
            close_retry_at: None,
        }
    }

    /// Stamp phase-1 completion and record the vote-phase duration.
    fn mark_vote_done(&mut self, stats: &ServerStats, now: Instant) {
        let vote = now.saturating_duration_since(self.started);
        self.timing.vote = Some(vote);
        self.vote_done_at = Some(now);
        stats.hist_vote_phase.record_micros(vote);
    }

    /// Stamp round close: record the update-phase duration and the
    /// end-to-end round latency.
    fn mark_round_done(&mut self, stats: &ServerStats, now: Instant) {
        let upd = now.saturating_duration_since(self.vote_done_at.unwrap_or(self.started));
        let total = now.saturating_duration_since(self.started);
        self.timing.update = Some(upd);
        self.timing.total = Some(total);
        stats.hist_update_phase.record_micros(upd);
        stats.hist_round_latency.record_micros(total);
    }

    /// Charge one full GIA/aggregate frame-set re-serve to `from`'s
    /// per-round budget. Returns false (and counts the suppression) when
    /// the source is over budget or the source table is full — the caller
    /// then sends nothing, so a small spoofed Poll cannot reflect the
    /// multi-frame broadcast set at a victim indefinitely. Sources that
    /// registered through `Join` (`registered`) bypass the table-size
    /// gate and get 4× the budget; absent authentication an attacker who
    /// spoofs a client's exact address can still burn that client's
    /// budget, so this bounds reflected volume rather than guaranteeing
    /// recovery under targeted spoofing.
    fn charge_reserve(
        &mut self,
        from: SocketAddr,
        registered: bool,
        limits: &JobLimits,
        stats: &ServerStats,
    ) -> bool {
        if !registered
            && self.serves.len() >= MAX_RESERVE_SOURCES
            && !self.serves.contains_key(&from)
        {
            ServerStats::bump(&stats.reserves_suppressed);
            return false;
        }
        let cap = if registered {
            limits.reserve_budget.saturating_mul(4)
        } else {
            limits.reserve_budget
        };
        let granted = self.serves.entry(from).or_insert(0);
        if *granted >= cap {
            ServerStats::bump(&stats.reserves_suppressed);
            return false;
        }
        *granted += 1;
        true
    }

    fn release(self, rf: &mut RegisterFile) {
        if let Some(a) = self.vote_agg {
            a.release(rf);
        }
        if let Some(a) = self.upd_agg {
            a.release(rf);
        }
    }

    // ---- phase 1 ---------------------------------------------------------

    /// Ingest one vote block; [`PacketFate::PhaseDone`] means phase 1
    /// just completed.
    #[allow(clippy::too_many_arguments)]
    fn vote_packet(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        client: u16,
        block: u32,
        elems: u32,
        payload: &[u8],
        local_max: f32,
        now: Instant,
    ) -> PacketFate {
        let d = spec.d as usize;
        let epb = spec.vote_block_bits();
        let block = block as usize;
        if block >= self.vote_wave.n_blocks {
            ServerStats::bump(&stats.decode_errors);
            return PacketFate::BadFrame;
        }
        let expect = epb.min(d - block * epb);
        if elems as usize != expect || payload.len() != expect.div_ceil(8) {
            ServerStats::bump(&stats.decode_errors);
            return PacketFate::BadFrame;
        }
        self.local_max = self.local_max.max(local_max);
        if block < self.vote_wave.start {
            ServerStats::bump(&stats.duplicates);
            return PacketFate::Duplicate;
        }
        // Only a frame that survives validation (and isn't a stale-block
        // replay) counts as traffic for idle register reclamation. The
        // previous touch is the phase's final inter-arrival wait if this
        // packet completes it — the straggler gap.
        let prev_touch = self.last_touch;
        self.last_touch = now;
        // Make sure the resident wave has registers (lazy allocation also
        // drains any spill that became resident).
        if self.vote_agg.is_none() && self.pump_vote(spec, rf, stats, now) {
            return Self::phase_done(stats, prev_touch, now);
        }
        if block < self.vote_wave.start {
            // The pump advanced past this block on drained spill — the
            // packet is a duplicate of an already-aggregated contribution.
            ServerStats::bump(&stats.duplicates);
            return PacketFate::Duplicate;
        }
        if self.vote_agg.is_some() && block < self.vote_wave.end() {
            let rel = block - self.vote_wave.start;
            let mark = self.vote_agg.as_mut().unwrap().ingest(client as usize, rel, payload);
            if mark == Mark::Duplicate {
                ServerStats::bump(&stats.duplicates);
                return PacketFate::Duplicate;
            }
            self.vote_part.record(client, block);
        } else {
            // Beyond the register window (or the window is stalled on
            // memory): spill to host memory until the wave advances.
            // Retransmissions during a stall must not grow the spill, so
            // dedup on (block, client) and cap the entries — dropped
            // spill is re-delivered by the client's retransmission.
            let key = (block as u32, client);
            if self.vote_spill.contains_key(&key) {
                ServerStats::bump(&stats.duplicates);
                return PacketFate::Duplicate;
            } else if self.vote_spill.len() >= self.spill_cap {
                ServerStats::bump(&stats.spill_dropped);
                return PacketFate::SpillDropped;
            }
            self.vote_spill.insert(key, payload.to_vec());
            self.vote_part.record(client, block);
            ServerStats::bump(&stats.spilled);
            return PacketFate::Spilled;
        }
        if self.pump_vote(spec, rf, stats, now) {
            Self::phase_done(stats, prev_touch, now)
        } else {
            PacketFate::Accepted
        }
    }

    /// A data packet just completed its phase: record the straggler gap
    /// (the wait for this final contribution) and report the fate.
    fn phase_done(stats: &ServerStats, prev_touch: Instant, now: Instant) -> PacketFate {
        stats.hist_straggler_gap.record_micros(now.saturating_duration_since(prev_touch));
        PacketFate::PhaseDone
    }

    /// Allocate/retire vote waves until progress stops. Returns true when
    /// the whole vote block space has been aggregated.
    fn pump_vote(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        now: Instant,
    ) -> bool {
        let d = spec.d as usize;
        let epb = spec.vote_block_bits();
        loop {
            if self.vote_wave.done() {
                return true;
            }
            if self.vote_agg.is_none() {
                let lo_dim = self.vote_wave.start * epb;
                let wave_dims = (self.vote_wave.end() * epb).min(d) - lo_dim;
                match VoteAggregator::new(
                    rf,
                    wave_dims,
                    spec.n_clients as usize,
                    spec.threshold_a as usize,
                    epb,
                ) {
                    Ok(agg) => {
                        if self.vote_wave.start > 0 {
                            ServerStats::bump(&stats.waves);
                        }
                        self.end_stall(stats, now);
                        self.vote_agg = Some(agg);
                        self.drain_vote_spill(stats);
                    }
                    Err(_) => {
                        ServerStats::bump(&stats.register_stalls);
                        self.stall_since.get_or_insert(now);
                        return false;
                    }
                }
            }
            if !self.vote_agg.as_ref().is_some_and(|a| a.all_complete()) {
                return false;
            }
            let agg = self.vote_agg.take().unwrap();
            let lo_dim = self.vote_wave.start * epb;
            let wave_dims = agg.counters().len();
            self.counters[lo_dim..lo_dim + wave_dims].copy_from_slice(agg.counters());
            agg.release(rf);
            self.vote_wave.start = self.vote_wave.end();
        }
    }

    /// A wave just won registers: if the round was stalled on the
    /// register file, record how long the stall spanned.
    fn end_stall(&mut self, stats: &ServerStats, now: Instant) {
        if let Some(t0) = self.stall_since.take() {
            stats.hist_register_stall.record_micros(now.saturating_duration_since(t0));
        }
    }

    fn drain_vote_spill(&mut self, stats: &ServerStats) {
        let (start, end) = (self.vote_wave.start, self.vote_wave.end());
        // Entries at or past the window keep waiting; the rest drain.
        let keep = self.vote_spill.split_off(&(end as u32, 0));
        for ((block, client), payload) in std::mem::replace(&mut self.vote_spill, keep) {
            if (block as usize) < start {
                ServerStats::bump(&stats.duplicates);
            } else {
                let agg = self.vote_agg.as_mut().expect("resident vote wave");
                let rel = block as usize - start;
                if agg.ingest(client as usize, rel, &payload) == Mark::Duplicate {
                    ServerStats::bump(&stats.duplicates);
                }
            }
        }
    }

    /// Threshold the finished counters into the GIA and arm phase 2.
    fn finish_phase1(
        &mut self,
        spec: &JobSpec,
        memory_bytes: usize,
        stats: &ServerStats,
        now: Instant,
    ) {
        let d = spec.d as usize;
        let mut bytes = vec![0u8; d.div_ceil(8)];
        alu::threshold_votes(&self.counters, spec.threshold_a, &mut bytes);
        let gia = BitVec::from_bytes(d, &bytes);
        let encoded = golomb::encode(&gia);
        let k_s = gia.count_ones();
        let n_blocks = spec.update_n_blocks(k_s);
        let window = window_blocks(memory_bytes, spec.payload_budget as usize).min(n_blocks);
        self.upd_acc = vec![0i32; k_s];
        self.upd_wave = Wave { n_blocks, window, start: 0 };
        self.upd_part = Participation::new(spec.n_clients as usize, n_blocks);
        self.mark_vote_done(stats, now);
        if k_s == 0 {
            // Nothing passed the consensus threshold: the round's data
            // phase is trivially complete (and its update phase lasted
            // zero time, which the latency histograms record as such).
            self.upd_wave.start = self.upd_wave.n_blocks;
            self.agg_done = true;
            self.mark_round_done(stats, now);
            ServerStats::bump(&stats.rounds_completed);
        }
        self.gia = Some(GiaReady { gia, encoded, global_max: self.local_max });
    }

    /// Forced phase-1 retirement (quorum met, deadline elapsed): retire
    /// every remaining vote wave with whatever has arrived — a missing
    /// contribution is implicitly a zero bitmap, which is exactly what an
    /// abstaining client would have voted. Spill drains into each wave
    /// before it retires, so every counted contribution lands in the
    /// counters. Returns false when a wave cannot win registers right now
    /// (the caller retries after a backoff).
    fn force_votes(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        now: Instant,
    ) -> bool {
        let d = spec.d as usize;
        let epb = spec.vote_block_bits();
        while !self.vote_wave.done() {
            if self.vote_agg.is_none() {
                let lo_dim = self.vote_wave.start * epb;
                let wave_dims = (self.vote_wave.end() * epb).min(d) - lo_dim;
                match VoteAggregator::new(
                    rf,
                    wave_dims,
                    spec.n_clients as usize,
                    spec.threshold_a as usize,
                    epb,
                ) {
                    Ok(agg) => {
                        if self.vote_wave.start > 0 {
                            ServerStats::bump(&stats.waves);
                        }
                        self.end_stall(stats, now);
                        self.vote_agg = Some(agg);
                        self.drain_vote_spill(stats);
                    }
                    Err(_) => {
                        ServerStats::bump(&stats.register_stalls);
                        self.stall_since.get_or_insert(now);
                        return false;
                    }
                }
            }
            let agg = self.vote_agg.take().unwrap();
            let lo_dim = self.vote_wave.start * epb;
            let wave_dims = agg.counters().len();
            self.counters[lo_dim..lo_dim + wave_dims].copy_from_slice(agg.counters());
            agg.release(rf);
            self.vote_wave.start = self.vote_wave.end();
        }
        self.vote_spill.clear();
        true
    }

    /// Forced phase-2 retirement — the update twin of
    /// [`RoundState::force_votes`] (missing lanes are implicitly zero).
    fn force_updates(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        now: Instant,
    ) -> bool {
        let k_s = self.upd_acc.len();
        let epb = spec.update_block_lanes();
        while !self.upd_wave.done() {
            if self.upd_agg.is_none() {
                let lo_lane = self.upd_wave.start * epb;
                let wave_lanes = (self.upd_wave.end() * epb).min(k_s) - lo_lane;
                match UpdateAggregator::new(rf, wave_lanes, spec.n_clients as usize, epb) {
                    Ok(agg) => {
                        if self.upd_wave.start > 0 {
                            ServerStats::bump(&stats.waves);
                        }
                        self.end_stall(stats, now);
                        self.upd_agg = Some(agg);
                        self.drain_update_spill(stats);
                    }
                    Err(_) => {
                        ServerStats::bump(&stats.register_stalls);
                        self.stall_since.get_or_insert(now);
                        return false;
                    }
                }
            }
            let agg = self.upd_agg.take().unwrap();
            let lo_lane = self.upd_wave.start * epb;
            let wave_lanes = agg.aggregate().len();
            self.upd_acc[lo_lane..lo_lane + wave_lanes].copy_from_slice(agg.aggregate());
            ServerStats::add(&stats.overflow_lanes, agg.overflow_lanes());
            agg.release(rf);
            self.upd_wave.start = self.upd_wave.end();
        }
        self.upd_spill.clear();
        true
    }

    /// The instant at which this round's open phase becomes eligible for
    /// a quorum close, `None` when no such close is pending (legacy
    /// all-N, quorum not yet met, or the phase already closed). After a
    /// register-stalled close attempt this is the retry instant, which
    /// keeps the job's timer from re-demanding an elapsed deadline.
    fn quorum_deadline(&self, quorum: u16, phase_deadline: Duration) -> Option<Instant> {
        if quorum == 0 {
            return None;
        }
        if self.gia.is_none() {
            (self.vote_part.complete >= quorum)
                .then(|| self.close_retry_at.unwrap_or(self.started + phase_deadline))
        } else if !self.agg_done {
            let armed = self.upd_started?;
            (self.upd_part.complete >= quorum)
                .then(|| self.close_retry_at.unwrap_or(armed + phase_deadline))
        } else {
            None
        }
    }

    // ---- phase 2 ---------------------------------------------------------

    /// Ingest one update block; [`PacketFate::PhaseDone`] means phase 2
    /// just completed.
    #[allow(clippy::too_many_arguments)]
    fn update_packet(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        client: u16,
        block: u32,
        elems: u32,
        payload: &[u8],
        now: Instant,
    ) -> PacketFate {
        let k_s = self.upd_acc.len();
        let epb = spec.update_block_lanes();
        let block = block as usize;
        if block >= self.upd_wave.n_blocks {
            ServerStats::bump(&stats.decode_errors);
            return PacketFate::BadFrame;
        }
        let expect = epb.min(k_s - (block * epb).min(k_s));
        if elems as usize != expect || payload.len() != expect * 4 {
            ServerStats::bump(&stats.decode_errors);
            return PacketFate::BadFrame;
        }
        if block < self.upd_wave.start {
            ServerStats::bump(&stats.duplicates);
            return PacketFate::Duplicate;
        }
        // See vote_packet: validated, non-stale traffic only.
        let prev_touch = self.last_touch;
        self.last_touch = now;
        if self.upd_agg.is_none() && self.pump_update(spec, rf, stats, now) {
            return Self::phase_done(stats, prev_touch, now);
        }
        if block < self.upd_wave.start {
            ServerStats::bump(&stats.duplicates);
            return PacketFate::Duplicate;
        }
        if self.upd_agg.is_some() && block < self.upd_wave.end() {
            let lanes: Vec<i32> = lanes_iter(payload).collect();
            let rel = block - self.upd_wave.start;
            let mark = self.upd_agg.as_mut().unwrap().ingest(client as usize, rel, &lanes);
            if mark == Mark::Duplicate {
                ServerStats::bump(&stats.duplicates);
                return PacketFate::Duplicate;
            }
            self.upd_part.record(client, block);
        } else {
            // Same dedup + cap discipline as the vote spill.
            let key = (block as u32, client);
            if self.upd_spill.contains_key(&key) {
                ServerStats::bump(&stats.duplicates);
                return PacketFate::Duplicate;
            } else if self.upd_spill.len() >= self.spill_cap {
                ServerStats::bump(&stats.spill_dropped);
                return PacketFate::SpillDropped;
            }
            let lanes: Vec<i32> = lanes_iter(payload).collect();
            self.upd_spill.insert(key, lanes);
            self.upd_part.record(client, block);
            ServerStats::bump(&stats.spilled);
            return PacketFate::Spilled;
        }
        if self.pump_update(spec, rf, stats, now) {
            Self::phase_done(stats, prev_touch, now)
        } else {
            PacketFate::Accepted
        }
    }

    fn pump_update(
        &mut self,
        spec: &JobSpec,
        rf: &mut RegisterFile,
        stats: &ServerStats,
        now: Instant,
    ) -> bool {
        let k_s = self.upd_acc.len();
        let epb = spec.update_block_lanes();
        loop {
            if self.upd_wave.done() {
                return true;
            }
            if self.upd_agg.is_none() {
                let lo_lane = self.upd_wave.start * epb;
                let wave_lanes = (self.upd_wave.end() * epb).min(k_s) - lo_lane;
                match UpdateAggregator::new(rf, wave_lanes, spec.n_clients as usize, epb) {
                    Ok(agg) => {
                        if self.upd_wave.start > 0 {
                            ServerStats::bump(&stats.waves);
                        }
                        self.end_stall(stats, now);
                        self.upd_agg = Some(agg);
                        self.drain_update_spill(stats);
                    }
                    Err(_) => {
                        ServerStats::bump(&stats.register_stalls);
                        self.stall_since.get_or_insert(now);
                        return false;
                    }
                }
            }
            if !self.upd_agg.as_ref().is_some_and(|a| a.all_complete()) {
                return false;
            }
            let agg = self.upd_agg.take().unwrap();
            let lo_lane = self.upd_wave.start * epb;
            let wave_lanes = agg.aggregate().len();
            self.upd_acc[lo_lane..lo_lane + wave_lanes].copy_from_slice(agg.aggregate());
            ServerStats::add(&stats.overflow_lanes, agg.overflow_lanes());
            agg.release(rf);
            self.upd_wave.start = self.upd_wave.end();
        }
    }

    fn drain_update_spill(&mut self, stats: &ServerStats) {
        let (start, end) = (self.upd_wave.start, self.upd_wave.end());
        let keep = self.upd_spill.split_off(&(end as u32, 0));
        for ((block, client), lanes) in std::mem::replace(&mut self.upd_spill, keep) {
            if (block as usize) < start {
                ServerStats::bump(&stats.duplicates);
            } else {
                let agg = self.upd_agg.as_mut().expect("resident update wave");
                let rel = block as usize - start;
                if agg.ingest(client as usize, rel, &lanes) == Mark::Duplicate {
                    ServerStats::bump(&stats.duplicates);
                }
            }
        }
    }
}

/// Configured half of a job (exists after the first valid `Join`).
struct JobState {
    spec: JobSpec,
    registers: RegisterFile,
    clients: HashMap<u16, SocketAddr>,
    rounds: BTreeMap<u32, RoundState>,
}

/// One tenant of the aggregation server.
pub struct Job {
    id: u32,
    profile: PsProfile,
    limits: JobLimits,
    stats: Arc<ServerStats>,
    /// Host-memory accountant this job's worst-case round footprint is
    /// reserved against at configure time (shared across a shard set).
    budget: Arc<HostBudget>,
    /// Bytes currently reserved in `budget` (released on drop).
    reserved: usize,
    /// Datagram-buffer pool every emitted frame draws on; backends feed
    /// transmitted buffers back through [`Job::recycle`].
    scratch: FrameScratch,
    /// Reused holder for a broadcast's per-chunk template frames
    /// (encoded once, fanned out per destination).
    templates: Vec<Vec<u8>>,
    /// Reused destination list for multicast fan-out.
    dests: Vec<SocketAddr>,
    /// Reused lane-serialisation buffer for aggregate chunks.
    lane_buf: Vec<u8>,
    /// Reused outer `Outgoing` vectors (returned by [`Job::recycle`]).
    out_pool: Vec<Outgoing>,
    /// Optional flight recorder; when attached, every frame verdict is
    /// recorded (a branch and an atomic-free ring write — no per-frame
    /// allocation either way).
    recorder: Option<Arc<FlightRecorder>>,
    state: Option<JobState>,
}

/// Outer `Outgoing` vectors kept for reuse (one per in-flight
/// [`JobOutput`]; backends hold at most a couple at a time).
const MAX_OUT_POOL: usize = 8;

/// How many completed rounds a job keeps for retransmitted polls.
const ROUND_HISTORY: u32 = 3;
/// Hard cap on simultaneously live round states per job: bounds memory
/// against a participant spraying round numbers without letting one bogus
/// frame wedge in-progress rounds (oldest-first eviction). Crate-visible
/// because the `Join`-time [`HostBudget`] reservation is
/// `host_bytes_per_round × MAX_LIVE_ROUNDS` and tests size budgets
/// from the same figure.
pub(crate) const MAX_LIVE_ROUNDS: usize = 8;

impl Job {
    /// Unconfigured job with default [`JobLimits`] (configured by the
    /// first valid `Join`).
    pub fn new(id: u32, profile: PsProfile, stats: Arc<ServerStats>) -> Self {
        Self::with_limits(id, profile, JobLimits::default(), stats)
    }

    /// Unconfigured job with explicit abuse limits (and a private
    /// host-byte accountant derived from them).
    pub fn with_limits(
        id: u32,
        profile: PsProfile,
        limits: JobLimits,
        stats: Arc<ServerStats>,
    ) -> Self {
        let budget = Arc::new(HostBudget::new(limits.host_bytes));
        Self::with_budget(id, profile, limits, budget, stats)
    }

    /// Unconfigured job charging its host-memory reservation against a
    /// shared accountant — the shard-set form: every shard daemon of one
    /// deployment passes the same [`HostBudget`], so a tenant's
    /// `host_bytes` is a global budget rather than a per-shard one.
    pub fn with_budget(
        id: u32,
        profile: PsProfile,
        limits: JobLimits,
        budget: Arc<HostBudget>,
        stats: Arc<ServerStats>,
    ) -> Self {
        Job {
            id,
            profile,
            limits,
            stats,
            budget,
            reserved: 0,
            scratch: FrameScratch::new(),
            templates: Vec::new(),
            dests: Vec::new(),
            lane_buf: Vec::new(),
            out_pool: Vec::new(),
            recorder: None,
            state: None,
        }
    }

    /// Attach a flight recorder: from here on every handled frame's
    /// verdict is recorded (ring overwrite, no steady-state allocation).
    pub fn attach_recorder(&mut self, recorder: Arc<FlightRecorder>) {
        self.recorder = Some(recorder);
    }

    /// True once a valid `Join` has fixed the job's spec.
    pub fn is_configured(&self) -> bool {
        self.state.is_some()
    }

    /// The agreed spec (None until configured).
    pub fn spec(&self) -> Option<&JobSpec> {
        self.state.as_ref().map(|s| &s.spec)
    }

    /// Finished GIA for a round (None until phase 1 completes).
    pub fn round_gia(&self, round: u32) -> Option<&BitVec> {
        let st = self.state.as_ref()?;
        st.rounds.get(&round)?.gia.as_ref().map(|g| &g.gia)
    }

    /// Finished aggregate lanes for a round (None until phase 2 completes).
    pub fn round_aggregate(&self, round: u32) -> Option<&[i32]> {
        let st = self.state.as_ref()?;
        let rs = st.rounds.get(&round)?;
        rs.agg_done.then_some(rs.upd_acc.as_slice())
    }

    /// Phase timings of a round, measured from the `now` values the
    /// caller fed in (None for a round this job never saw). Fields fill
    /// in as phases complete.
    pub fn round_timing(&self, round: u32) -> Option<RoundTiming> {
        let st = self.state.as_ref()?;
        st.rounds.get(&round).map(|rs| rs.timing)
    }

    /// Handle one decoded frame at time `now`; returns the datagrams to
    /// send plus the job's next timer deadline. Pure with respect to
    /// I/O: the caller owns the socket and the clock. The returned
    /// buffers are pooled — see [`Job::recycle`].
    pub fn handle(&mut self, frame: &Frame<'_>, from: SocketAddr, now: Instant) -> JobOutput {
        let mut frames = self.out_pool.pop().unwrap_or_default();
        self.handle_frames(frame, from, now, &mut frames);
        self.quorum_close_due(now, &mut frames);
        self.sync_pool_stats();
        JobOutput { frames, timer: self.next_timer() }
    }

    /// A timer deadline arrived: force-close quorum-eligible phases whose
    /// deadline elapsed (emitting their completion multicasts), then
    /// reclaim register aggregators from rounds whose traffic went idle.
    /// Backends call this when the `timer` of an earlier [`JobOutput`]
    /// expires — and only then, so an idle job costs zero wakeups (see
    /// `ServerStats::idle_wakeups`).
    pub fn on_tick(&mut self, now: Instant) -> JobOutput {
        let mut frames = self.out_pool.pop().unwrap_or_default();
        self.quorum_close_due(now, &mut frames);
        if let Some(st) = self.state.as_mut() {
            Self::reap_idle(st, None, now, &self.limits, &self.stats);
        }
        self.sync_pool_stats();
        JobOutput { frames, timer: self.next_timer() }
    }

    /// Hand a transmitted [`JobOutput`]'s buffers back to the pool so
    /// the next emission reuses them instead of allocating. Optional —
    /// a caller that drops the output instead merely costs allocations
    /// (counted in `ServerStats::pool_misses`).
    pub fn recycle(&mut self, mut frames: Outgoing) {
        for (buf, _) in frames.drain(..) {
            self.scratch.give(buf);
        }
        if self.out_pool.len() < MAX_OUT_POOL {
            self.out_pool.push(frames);
        }
    }

    /// Fold the scratch pool's since-last-call hit/miss counters into
    /// the shared daemon stats.
    fn sync_pool_stats(&mut self) {
        let (hits, misses) = self.scratch.drain_counters();
        if hits > 0 {
            ServerStats::add(&self.stats.frames_pooled, hits);
        }
        if misses > 0 {
            ServerStats::add(&self.stats.pool_misses, misses);
        }
    }

    /// Earliest pending deadline across this job's rounds: idle register
    /// reclamation for rounds holding aggregators, plus — for quorum jobs
    /// — the phase deadline of any round whose quorum is already met
    /// (when the quorum arrives *after* the deadline, the close happens
    /// inline on that frame, so no wakeup is needed for it). `None` when
    /// the job is quiescent and needs no wakeup at all.
    pub fn next_timer(&self) -> Option<Instant> {
        let st = self.state.as_ref()?;
        let idle = st
            .rounds
            .values()
            .filter(|rs| rs.vote_agg.is_some() || rs.upd_agg.is_some())
            .map(|rs| rs.last_touch + self.limits.idle_release_after);
        let quorum = st
            .rounds
            .values()
            .filter_map(|rs| rs.quorum_deadline(st.spec.quorum, self.limits.phase_deadline));
        idle.chain(quorum).min()
    }

    fn handle_frames(
        &mut self,
        frame: &Frame<'_>,
        from: SocketAddr,
        now: Instant,
        out: &mut Outgoing,
    ) {
        let h = frame.header;
        // Downlink kinds arriving at the server are reflections or
        // server-bound spoofs. They must be dropped *silently* — even a
        // small JoinAck/UNKNOWN reply would let a forged Gia/Aggregate
        // frame bounce traffic off this daemon at a victim address.
        let rec = self.recorder.as_deref();
        if matches!(
            h.kind,
            WireKind::JoinAck | WireKind::Gia | WireKind::Aggregate | WireKind::NotReady
        ) {
            ServerStats::bump(&self.stats.downlink_spoofs);
            trace(rec, self.id, &h, Some(from), TraceNote::DownlinkSpoof, now);
            return;
        }
        match h.kind {
            WireKind::Join => self.on_join(h, frame.payload, from, now, out),
            _ if self.state.is_none() => {
                trace(rec, self.id, &h, Some(from), TraceNote::UnknownJob, now);
                self.ack(h.client, h.round, JOIN_UNKNOWN_JOB, from, out)
            }
            WireKind::Vote => self.on_vote(h, frame.payload, from, now, out),
            WireKind::Update => self.on_update(h, frame.payload, from, now, out),
            WireKind::Poll => self.on_poll(h, from, now, out),
            // Unreachable: every uplink kind is matched above.
            _ => {}
        }
    }

    fn ack(&mut self, client: u16, round: u32, status: u32, to: SocketAddr, out: &mut Outgoing) {
        let h = Header::control(WireKind::JoinAck, self.id, client, round, status);
        out.push((self.scratch.encode(&h, &[]), to));
    }

    fn on_join(
        &mut self,
        h: Header,
        payload: &[u8],
        from: SocketAddr,
        now: Instant,
        out: &mut Outgoing,
    ) {
        // Clone the recorder handle so the trace closure borrows no part
        // of `self` (Join is rare — one Arc bump is nothing).
        let rec = self.recorder.clone();
        let id = self.id;
        let verdict = move |note| trace(rec.as_deref(), id, &h, Some(from), note, now);
        let spec = match JobSpec::decode(payload) {
            Ok(s) => s,
            Err(_) => {
                verdict(TraceNote::JoinRefused);
                return self.ack(h.client, h.round, JOIN_BAD_SPEC, from, out);
            }
        };
        // One resident block of either phase must fit this switch's
        // register file (vote: 2 bytes per dimension, update: the lanes).
        let min_block = (spec.vote_block_bits() * 2).max(spec.payload_budget as usize);
        if min_block > self.profile.memory_bytes || h.client >= spec.n_clients {
            verdict(TraceNote::JoinRefused);
            return self.ack(h.client, h.round, JOIN_BAD_SPEC, from, out);
        }
        if self.state.as_ref().is_some_and(|st| st.spec != spec) {
            verdict(TraceNote::JoinRefused);
            return self.ack(h.client, h.round, JOIN_SPEC_MISMATCH, from, out);
        }
        if self.state.is_none() {
            // Bound host-side allocation from an untrusted spec: every
            // live round pins counters/GIA/accumulator memory
            // proportional to d, and rounds are created by
            // unauthenticated data frames. The reservation goes through
            // the (possibly shard-shared) accountant, so in a sharded
            // deployment the tenant's shards draw on ONE budget.
            let worst = spec.host_bytes_per_round().saturating_mul(MAX_LIVE_ROUNDS);
            if !self.budget.try_reserve(self.id, worst) {
                verdict(TraceNote::JoinRefused);
                return self.ack(h.client, h.round, JOIN_BAD_SPEC, from, out);
            }
            self.reserved = worst;
            self.state = Some(JobState {
                spec,
                registers: RegisterFile::new(self.profile.memory_bytes),
                clients: HashMap::new(),
                rounds: BTreeMap::new(),
            });
            ServerStats::bump(&self.stats.jobs_created);
        }
        self.state.as_mut().unwrap().clients.insert(h.client, from);
        ServerStats::bump(&self.stats.joins);
        verdict(TraceNote::JoinAccepted);
        self.ack(h.client, h.round, JOIN_OK, from, out)
    }

    /// Create the round lazily and prune retired history. Only *completed*
    /// rounds age out by round distance (a single frame with a huge round
    /// number must not wedge in-progress rounds); total live rounds are
    /// bounded by oldest-first eviction.
    fn ensure_round(
        st: &mut JobState,
        round: u32,
        memory_bytes: usize,
        limits: &JobLimits,
        now: Instant,
    ) {
        if st.rounds.contains_key(&round) {
            return;
        }
        let cap = spill_cap(limits, &st.spec);
        st.rounds.insert(round, RoundState::new(&st.spec, memory_bytes, cap, now));
        let newest = *st.rounds.keys().next_back().unwrap();
        let cutoff = newest.saturating_sub(ROUND_HISTORY);
        let stale: Vec<u32> = st
            .rounds
            .iter()
            .filter(|(&r, rs)| r < cutoff && rs.agg_done)
            .map(|(&r, _)| r)
            .collect();
        for r in stale {
            if let Some(old) = st.rounds.remove(&r) {
                old.release(&mut st.registers);
            }
        }
        while st.rounds.len() > MAX_LIVE_ROUNDS {
            // Never evict the round we just created — the caller is about
            // to ingest into it.
            let oldest = *st.rounds.keys().find(|&&r| r != round).unwrap();
            if let Some(old) = st.rounds.remove(&oldest) {
                old.release(&mut st.registers);
            }
        }
    }

    /// Reclaim register aggregators from in-progress rounds with no recent
    /// traffic, so one abandoned (or merely stalled) round cannot hold the
    /// register file hostage while other rounds spill forever. The round's
    /// host state survives; if its clients return, their retransmissions
    /// rebuild the reclaimed wave through a fresh aggregator.
    fn reap_idle(
        st: &mut JobState,
        current: Option<u32>,
        now: Instant,
        limits: &JobLimits,
        stats: &ServerStats,
    ) {
        let JobState { registers, rounds, .. } = st;
        for (&r, rs) in rounds.iter_mut() {
            if Some(r) == current || (rs.vote_agg.is_none() && rs.upd_agg.is_none()) {
                continue;
            }
            if now.duration_since(rs.last_touch) < limits.idle_release_after {
                continue;
            }
            if let Some(a) = rs.vote_agg.take() {
                a.release(registers);
                ServerStats::bump(&stats.idle_releases);
            }
            if let Some(a) = rs.upd_agg.take() {
                a.release(registers);
                ServerStats::bump(&stats.idle_releases);
            }
        }
    }

    /// Force-close every quorum-eligible phase whose deadline elapsed,
    /// emitting the same completion multicasts as the organic close path
    /// so surviving clients do not spend a poll cycle discovering the
    /// result. Runs on every handled frame *and* every tick: the timer
    /// covers quorums that were met before the deadline, the inline call
    /// covers quorums completed by a frame arriving after it. A no-op
    /// for `quorum = 0` jobs — legacy all-N deployments keep
    /// bit-identical wire behaviour by construction.
    fn quorum_close_due(&mut self, now: Instant, out: &mut Outgoing) {
        let Some(st) = self.state.as_mut() else { return };
        let quorum = st.spec.quorum;
        if quorum == 0 {
            return;
        }
        let JobState { spec, registers, rounds, clients } = st;
        let spec = *spec;
        for (&round, rs) in rounds.iter_mut() {
            match rs.quorum_deadline(quorum, self.limits.phase_deadline) {
                Some(t) if now >= t => {}
                _ => continue,
            }
            if rs.gia.is_none() {
                // Phase 1: threshold what arrived; absent votes are zero.
                if !rs.force_votes(&spec, registers, &self.stats, now) {
                    rs.close_retry_at = Some(now + self.limits.idle_release_after);
                    continue;
                }
                rs.close_retry_at = None;
                rs.finish_phase1(&spec, self.profile.memory_bytes, &self.stats, now);
                ServerStats::bump(&self.stats.quorum_closes);
                if let Some(rec) = self.recorder.as_deref() {
                    rec.note(self.id, round, None, u16::MAX, None, TraceNote::QuorumClose, now);
                }
                Self::gia_templates(&mut self.scratch, &mut self.templates, self.id, round, rs, &spec);
                if rs.agg_done {
                    // Empty consensus under a forced close still answers
                    // the aggregate wait in the same multicast.
                    Self::agg_templates(
                        &mut self.scratch,
                        &mut self.lane_buf,
                        &mut self.templates,
                        self.id,
                        round,
                        rs,
                        &spec,
                    );
                }
            } else {
                // Phase 2: sum what arrived; absent updates are zero.
                if !rs.force_updates(&spec, registers, &self.stats, now) {
                    rs.close_retry_at = Some(now + self.limits.idle_release_after);
                    continue;
                }
                rs.close_retry_at = None;
                rs.agg_done = true;
                rs.mark_round_done(&self.stats, now);
                ServerStats::bump(&self.stats.rounds_completed);
                ServerStats::bump(&self.stats.quorum_closes);
                if let Some(rec) = self.recorder.as_deref() {
                    rec.note(self.id, round, None, u16::MAX, None, TraceNote::QuorumClose, now);
                }
                Self::agg_templates(
                    &mut self.scratch,
                    &mut self.lane_buf,
                    &mut self.templates,
                    self.id,
                    round,
                    rs,
                    &spec,
                );
            }
            self.dests.clear();
            self.dests.extend(clients.values().copied());
            Self::fan_out(&mut self.scratch, &mut self.templates, &self.dests, out);
        }
    }

    fn on_vote(
        &mut self,
        h: Header,
        payload: &[u8],
        from: SocketAddr,
        now: Instant,
        out: &mut Outgoing,
    ) {
        let rec = self.recorder.as_deref();
        let st = self.state.as_mut().unwrap();
        if h.client >= st.spec.n_clients {
            ServerStats::bump(&self.stats.decode_errors);
            trace(rec, self.id, &h, Some(from), TraceNote::BadFrame, now);
            return;
        }
        // The aux word is this client's local max-|U|, folded with max
        // into the global m every client later derives f from. A single
        // NaN/Inf (one hostile or broken client) would poison the scale
        // factor for the whole job — reject the frame at ingest.
        let local_max = f32::from_bits(h.aux);
        if !local_max.is_finite() {
            ServerStats::bump(&self.stats.non_finite_aux);
            trace(rec, self.id, &h, Some(from), TraceNote::NonFiniteAux, now);
            return;
        }
        Self::reap_idle(st, Some(h.round), now, &self.limits, &self.stats);
        Self::ensure_round(st, h.round, self.profile.memory_bytes, &self.limits, now);
        let JobState { spec, registers, rounds, clients } = st;
        let spec = *spec;
        let rs = rounds.get_mut(&h.round).unwrap();
        if rs.gia.is_some() {
            // Phase 1 already closed: count the straggler (under quorum
            // close this is the diagnosable trail of a client the round
            // went on without) and drop it. The client's own Poll (sent
            // on every timeout) re-serves the GIA under the per-source
            // budget — answering every retransmitted data frame with the
            // full set would be a reflection vector.
            ServerStats::bump(&self.stats.late_after_close);
            trace(rec, self.id, &h, Some(from), TraceNote::LateAfterClose, now);
            return;
        }
        let fate = rs.vote_packet(
            &spec,
            registers,
            &self.stats,
            h.client,
            h.block,
            h.elems,
            payload,
            local_max,
            now,
        );
        trace(rec, self.id, &h, Some(from), fate.note(TraceNote::PhaseOneDone), now);
        if fate != PacketFate::PhaseDone {
            return;
        }
        rs.finish_phase1(&spec, self.profile.memory_bytes, &self.stats, now);
        Self::gia_templates(&mut self.scratch, &mut self.templates, self.id, h.round, rs, &spec);
        if rs.agg_done {
            // Empty consensus: phase 2 closed inside finish_phase1, so
            // this multicast is the only chance to answer the clients'
            // (empty) aggregate wait without costing each a poll cycle.
            trace(rec, self.id, &h, Some(from), TraceNote::RoundDone, now);
            Self::agg_templates(
                &mut self.scratch,
                &mut self.lane_buf,
                &mut self.templates,
                self.id,
                h.round,
                rs,
                &spec,
            );
        }
        self.dests.clear();
        self.dests.extend(clients.values().copied());
        Self::fan_out(&mut self.scratch, &mut self.templates, &self.dests, out);
    }

    fn on_update(
        &mut self,
        h: Header,
        payload: &[u8],
        from: SocketAddr,
        now: Instant,
        out: &mut Outgoing,
    ) {
        let rec = self.recorder.as_deref();
        let st = self.state.as_mut().unwrap();
        if h.client >= st.spec.n_clients {
            ServerStats::bump(&self.stats.decode_errors);
            trace(rec, self.id, &h, Some(from), TraceNote::BadFrame, now);
            return;
        }
        Self::reap_idle(st, Some(h.round), now, &self.limits, &self.stats);
        let JobState { spec, registers, rounds, clients } = st;
        let spec = *spec;
        let Some(rs) = rounds.get_mut(&h.round) else {
            // Updates for an unknown round (e.g. pruned): nothing to join
            // them to — the client's poll will get NotReady.
            ServerStats::bump(&self.stats.decode_errors);
            trace(rec, self.id, &h, Some(from), TraceNote::BadFrame, now);
            return;
        };
        if rs.gia.is_none() {
            // Phase 2 data before phase 1 finished — protocol violation or
            // heavy reordering; drop and let the client retransmit.
            ServerStats::bump(&self.stats.decode_errors);
            trace(rec, self.id, &h, Some(from), TraceNote::BadFrame, now);
            return;
        }
        if rs.agg_done {
            // Round already closed: as with late votes, recovery goes
            // through the budgeted Poll path, not data-frame echoes.
            ServerStats::bump(&self.stats.late_after_close);
            trace(rec, self.id, &h, Some(from), TraceNote::LateAfterClose, now);
            return;
        }
        // First Update frame of the round arms the phase-2 quorum
        // deadline (harmless for quorum = 0 jobs — never consulted).
        rs.upd_started.get_or_insert(now);
        let fate = rs.update_packet(
            &spec,
            registers,
            &self.stats,
            h.client,
            h.block,
            h.elems,
            payload,
            now,
        );
        trace(rec, self.id, &h, Some(from), fate.note(TraceNote::RoundDone), now);
        if fate != PacketFate::PhaseDone {
            return;
        }
        rs.agg_done = true;
        rs.mark_round_done(&self.stats, now);
        ServerStats::bump(&self.stats.rounds_completed);
        Self::agg_templates(
            &mut self.scratch,
            &mut self.lane_buf,
            &mut self.templates,
            self.id,
            h.round,
            rs,
            &spec,
        );
        self.dests.clear();
        self.dests.extend(clients.values().copied());
        Self::fan_out(&mut self.scratch, &mut self.templates, &self.dests, out);
    }

    fn on_poll(&mut self, h: Header, from: SocketAddr, now: Instant, out: &mut Outgoing) {
        let rec = self.recorder.as_deref();
        let st = self.state.as_mut().unwrap();
        if h.client >= st.spec.n_clients {
            ServerStats::bump(&self.stats.decode_errors);
            trace(rec, self.id, &h, Some(from), TraceNote::BadFrame, now);
            return;
        }
        let JobState { spec, rounds, clients, .. } = st;
        let spec = *spec;
        let not_ready = Header::control(WireKind::NotReady, self.id, h.client, h.round, h.aux);
        let Some(rs) = rounds.get_mut(&h.round) else {
            trace(rec, self.id, &h, Some(from), TraceNote::NotReady, now);
            out.push((self.scratch.encode(&not_ready, &[]), from));
            return;
        };
        let serving = (h.aux == WireKind::Gia as u32 && rs.gia.is_some())
            || (h.aux == WireKind::Aggregate as u32 && rs.agg_done);
        if !serving {
            trace(rec, self.id, &h, Some(from), TraceNote::NotReady, now);
            out.push((self.scratch.encode(&not_ready, &[]), from));
            return;
        }
        // A poll's reply is the full multi-frame set — charge it to the
        // per-source reflection budget. Addresses that came through Join
        // keep a seat at the table and get extra budget headroom.
        let registered = clients.values().any(|a| *a == from);
        if !rs.charge_reserve(from, registered, &self.limits, &self.stats) {
            trace(rec, self.id, &h, Some(from), TraceNote::PollSuppressed, now);
            return;
        }
        trace(rec, self.id, &h, Some(from), TraceNote::PollServed, now);
        if h.aux == WireKind::Gia as u32 {
            Self::gia_templates(&mut self.scratch, &mut self.templates, self.id, h.round, rs, &spec);
        } else {
            Self::agg_templates(
                &mut self.scratch,
                &mut self.lane_buf,
                &mut self.templates,
                self.id,
                h.round,
                rs,
                &spec,
            );
        }
        self.dests.clear();
        self.dests.push(from);
        Self::fan_out(&mut self.scratch, &mut self.templates, &self.dests, out);
    }

    /// Encode the GIA broadcast once into pooled template buffers;
    /// clients ignore the destination field on downlink frames, so one
    /// template set serves every receiver via [`Self::fan_out`].
    fn gia_templates(
        scratch: &mut FrameScratch,
        templates: &mut Vec<Vec<u8>>,
        job: u32,
        round: u32,
        rs: &RoundState,
        spec: &JobSpec,
    ) {
        let ready = rs.gia.as_ref().expect("gia ready");
        let budget = spec.payload_budget as usize;
        let n_blocks = byte_chunk_bounds(ready.encoded.len(), budget).count() as u32;
        for (i, (lo, hi)) in byte_chunk_bounds(ready.encoded.len(), budget).enumerate() {
            let chunk = &ready.encoded[lo..hi];
            let header = Header {
                kind: WireKind::Gia,
                client: u16::MAX,
                job,
                round,
                block: i as u32,
                n_blocks,
                elems: chunk.len() as u32,
                aux: ready.global_max.to_bits(),
            };
            templates.push(scratch.encode(&header, chunk));
        }
    }

    /// Encode the aggregate broadcast once into pooled template buffers
    /// (see [`Self::gia_templates`]).
    fn agg_templates(
        scratch: &mut FrameScratch,
        lane_buf: &mut Vec<u8>,
        templates: &mut Vec<Vec<u8>>,
        job: u32,
        round: u32,
        rs: &RoundState,
        spec: &JobSpec,
    ) {
        let budget = spec.payload_budget as usize;
        let n_blocks = update_chunk_bounds(rs.upd_acc.len(), budget).count() as u32;
        for (i, (lo, hi)) in update_chunk_bounds(rs.upd_acc.len(), budget).enumerate() {
            encode_lanes_into(lane_buf, &rs.upd_acc[lo..hi]);
            let header = Header {
                kind: WireKind::Aggregate,
                client: u16::MAX,
                job,
                round,
                block: i as u32,
                n_blocks,
                elems: (hi - lo) as u32,
                aux: rs.upd_acc.len() as u32,
            };
            templates.push(scratch.encode(&header, lane_buf));
        }
    }

    /// Address the template frame set to every destination, preserving
    /// the historical order (per destination: the full set in block
    /// order). Every destination but the last copies through the pool;
    /// the last takes ownership, so the templates drain back to byte
    /// buffers with zero waste. No destinations ⇒ templates recycle.
    fn fan_out(
        scratch: &mut FrameScratch,
        templates: &mut Vec<Vec<u8>>,
        dests: &[SocketAddr],
        out: &mut Outgoing,
    ) {
        match dests.split_last() {
            None => {
                for t in templates.drain(..) {
                    scratch.give(t);
                }
            }
            Some((&last, rest)) => {
                for &dest in rest {
                    for t in templates.iter() {
                        out.push((scratch.copy(t), dest));
                    }
                }
                for t in templates.drain(..) {
                    out.push((t, last));
                }
            }
        }
    }
}

impl Drop for Job {
    fn drop(&mut self) {
        // Hand the configure-time reservation back to the accountant so
        // an evicted or retired job frees its tenant's budget (matters
        // when the accountant is shared across a shard set).
        if self.reserved > 0 {
            self.budget.release(self.id, self.reserved);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::deduce_gia;
    use crate::wire::{
        decode_frame, encode_frame, update_chunks, vote_chunks, ChunkAssembler, ShardPlan,
    };

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn mkspec(d: u32, n_clients: u16, threshold_a: u16, payload_budget: u16) -> JobSpec {
        JobSpec { d, n_clients, threshold_a, payload_budget, shard: ShardPlan::single(), quorum: 0 }
    }

    fn profile(memory: usize) -> PsProfile {
        PsProfile { memory_bytes: memory, ..PsProfile::high() }
    }

    fn join_frame(job: u32, client: u16, spec: &JobSpec) -> Vec<u8> {
        encode_frame(&Header::control(WireKind::Join, job, client, 0, 0), &spec.encode())
    }

    fn vote_frames(job: u32, client: u16, round: u32, bits: &BitVec, spec: &JobSpec) -> Vec<Vec<u8>> {
        let chunks = vote_chunks(bits, spec.payload_budget as usize);
        let n_blocks = chunks.len() as u32;
        chunks
            .iter()
            .enumerate()
            .map(|(i, (dims, bytes))| {
                encode_frame(
                    &Header {
                        kind: WireKind::Vote,
                        client,
                        job,
                        round,
                        block: i as u32,
                        n_blocks,
                        elems: *dims as u32,
                        aux: 1.0f32.to_bits(),
                    },
                    bytes,
                )
            })
            .collect()
    }

    fn update_frames(
        job: u32,
        client: u16,
        round: u32,
        lanes: &[i32],
        spec: &JobSpec,
    ) -> Vec<Vec<u8>> {
        let chunks = update_chunks(lanes, spec.payload_budget as usize);
        let n_blocks = chunks.len() as u32;
        chunks
            .iter()
            .enumerate()
            .map(|(i, (n, bytes))| {
                encode_frame(
                    &Header {
                        kind: WireKind::Update,
                        client,
                        job,
                        round,
                        block: i as u32,
                        n_blocks,
                        elems: *n as u32,
                        aux: 0,
                    },
                    bytes,
                )
            })
            .collect()
    }

    fn feed(job: &mut Job, datagram: &[u8], from: SocketAddr) -> Outgoing {
        let frame = decode_frame(datagram).unwrap();
        job.handle(&frame, from, Instant::now()).frames
    }

    fn make_job(spec: &JobSpec, memory: usize) -> Job {
        let stats = Arc::new(ServerStats::default());
        let mut job = Job::new(9, profile(memory), stats);
        for c in 0..spec.n_clients {
            let out = feed(&mut job, &join_frame(9, c, spec), addr(4000 + c));
            let ackf = decode_frame(&out[0].0).unwrap();
            assert_eq!(ackf.header.kind, WireKind::JoinAck);
            assert_eq!(ackf.header.aux, JOIN_OK);
        }
        job
    }

    #[test]
    fn full_round_matches_host_reference() {
        let spec = mkspec(100, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        let v0 = BitVec::from_indices(100, &[0, 5, 64, 99]);
        let v1 = BitVec::from_indices(100, &[5, 64, 70]);

        let mut gia_out = Vec::new();
        for (c, v) in [(0u16, &v0), (1u16, &v1)] {
            for f in vote_frames(9, c, 1, v, &spec) {
                gia_out = feed(&mut job, &f, addr(4000 + c));
            }
        }
        // Completion multicast: GIA chunks to both clients.
        assert!(!gia_out.is_empty());
        let expected = deduce_gia(&[v0.clone(), v1.clone()], 1);
        assert_eq!(job.round_gia(1), Some(&expected));
        let k_s = expected.count_ones();

        // Reassemble one client's copy and check it Golomb-decodes.
        let mut asm = ChunkAssembler::new(
            decode_frame(&gia_out[0].0).unwrap().header.n_blocks as usize,
        );
        for (bytes, to) in &gia_out {
            let f = decode_frame(bytes).unwrap();
            if *to == addr(4000) && f.header.kind == WireKind::Gia {
                asm.insert(f.header.block as usize, f.payload);
            }
        }
        assert!(asm.is_complete());
        assert_eq!(golomb::decode(&asm.assemble()).unwrap(), expected);

        // Phase 2: two aligned lane vectors.
        let l0: Vec<i32> = (0..k_s as i32).collect();
        let l1: Vec<i32> = (0..k_s as i32).map(|x| 10 * x).collect();
        let mut agg_out = Vec::new();
        for (c, l) in [(0u16, &l0), (1u16, &l1)] {
            for f in update_frames(9, c, 1, l, &spec) {
                agg_out = feed(&mut job, &f, addr(4000 + c));
            }
        }
        assert!(!agg_out.is_empty());
        let want: Vec<i32> = (0..k_s as i32).map(|x| 11 * x).collect();
        assert_eq!(job.round_aggregate(1), Some(&want[..]));
    }

    #[test]
    fn wave_spill_with_tiny_register_file() {
        // budget 8 → vote block = 64 dims = 128 B of counters; 200 B of
        // registers hold exactly one block, so d=100 (2 blocks) needs 2
        // waves and out-of-window packets spill.
        let spec = mkspec(100, 2, 2, 8);
        let mut job = make_job(&spec, 200);
        let votes: Vec<BitVec> =
            (0..2).map(|c| BitVec::from_indices(100, &[c, 50, 80, 99])).collect();
        let frames: Vec<Vec<Vec<u8>>> =
            (0..2).map(|c| vote_frames(9, c as u16, 0, &votes[c], &spec)).collect();

        // Block 1 first from client 0 → must spill (window holds block 0).
        assert!(feed(&mut job, &frames[0][1], addr(4000)).is_empty());
        assert_eq!(job.stats.spilled.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(feed(&mut job, &frames[0][0], addr(4000)).is_empty());
        assert!(feed(&mut job, &frames[1][0], addr(4001)).is_empty());
        // Wave 0 retires, spill drains; client 1's block 1 completes it.
        let out = feed(&mut job, &frames[1][1], addr(4001));
        assert!(!out.is_empty(), "phase 1 should complete");
        assert_eq!(job.stats.waves.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(job.round_gia(0), Some(&deduce_gia(&votes, 2)));
        // Registers fully returned after the phase.
        let st = job.state.as_ref().unwrap();
        assert_eq!(st.registers.used(), 0);
    }

    #[test]
    fn duplicates_are_suppressed() {
        let spec = mkspec(64, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        let v = BitVec::from_indices(64, &[1, 2, 3]);
        let f0 = &vote_frames(9, 0, 0, &v, &spec)[0];
        assert!(feed(&mut job, f0, addr(4000)).is_empty());
        assert!(feed(&mut job, f0, addr(4000)).is_empty());
        assert_eq!(job.stats.duplicates.load(std::sync::atomic::Ordering::Relaxed), 1);
        // Completing the phase then retransmitting is dropped silently —
        // a straggler recovers the GIA through its Poll, not data echoes.
        let f1 = &vote_frames(9, 1, 0, &v, &spec)[0];
        assert!(!feed(&mut job, f1, addr(4001)).is_empty());
        assert!(feed(&mut job, f0, addr(4000)).is_empty());
        let poll = encode_frame(
            &Header {
                kind: WireKind::Poll,
                client: 0,
                job: 9,
                round: 0,
                block: 0,
                n_blocks: 0,
                elems: 0,
                aux: WireKind::Gia as u32,
            },
            &[],
        );
        let replay = feed(&mut job, &poll, addr(4000));
        assert!(!replay.is_empty(), "poll should re-serve the GIA");
        assert_eq!(decode_frame(&replay[0].0).unwrap().header.kind, WireKind::Gia);
        // Counters only saw each contribution once.
        assert_eq!(job.round_gia(0).unwrap().count_ones(), 3);
    }

    #[test]
    fn join_validation() {
        let stats = Arc::new(ServerStats::default());
        let mut job = Job::new(1, profile(100), stats);
        // Budget too large for 100 B of registers (needs 16·budget).
        let spec = mkspec(64, 2, 1, 64);
        let out = feed(&mut job, &join_frame(1, 0, &spec), addr(5000));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_BAD_SPEC);
        assert!(!job.is_configured());

        // Valid spec creates the job; a conflicting re-join is refused.
        let ok = mkspec(64, 2, 1, 4);
        let out = feed(&mut job, &join_frame(1, 0, &ok), addr(5000));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
        let conflicting = JobSpec { threshold_a: 2, ..ok };
        let out = feed(&mut job, &join_frame(1, 1, &conflicting), addr(5001));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_SPEC_MISMATCH);
        // Data for an unconfigured job id elsewhere gets JOIN_UNKNOWN_JOB.
        let mut fresh = Job::new(2, profile(1 << 20), Arc::new(ServerStats::default()));
        let v = BitVec::from_indices(64, &[0]);
        let out = feed(&mut fresh, &vote_frames(2, 0, 0, &v, &ok)[0], addr(5002));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_UNKNOWN_JOB);
    }

    #[test]
    fn shard_plan_mismatch_is_refused() {
        // A shard's clients must agree on the whole spec, plan included:
        // a client that believes a different slice (or no sharding at
        // all) lives at this server must not silently join and feed
        // blocks of the wrong sub-model into the counters.
        let stats = Arc::new(ServerStats::default());
        let mut job = Job::new(7, profile(1 << 20), stats);
        let shard0 =
            JobSpec { shard: ShardPlan { n_shards: 2, shard_id: 0 }, ..mkspec(64, 2, 1, 8) };
        let out = feed(&mut job, &join_frame(7, 0, &shard0), addr(4300));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
        let other = JobSpec { shard: ShardPlan { n_shards: 2, shard_id: 1 }, ..shard0 };
        let out = feed(&mut job, &join_frame(7, 1, &other), addr(4301));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_SPEC_MISMATCH);
        let unsharded = JobSpec { shard: ShardPlan::single(), ..shard0 };
        let out = feed(&mut job, &join_frame(7, 1, &unsharded), addr(4301));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_SPEC_MISMATCH);
        // The matching plan joins fine.
        let out = feed(&mut job, &join_frame(7, 1, &shard0), addr(4301));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
    }

    #[test]
    fn poll_not_ready_then_ready() {
        let spec = mkspec(64, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        let poll = encode_frame(
            &Header {
                kind: WireKind::Poll,
                client: 0,
                job: 9,
                round: 0,
                block: 0,
                n_blocks: 0,
                elems: 0,
                aux: WireKind::Gia as u32,
            },
            &[],
        );
        let out = feed(&mut job, &poll, addr(4000));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.kind, WireKind::NotReady);
        let v = BitVec::from_indices(64, &[7]);
        for c in 0..2u16 {
            feed(&mut job, &vote_frames(9, c, 0, &v, &spec)[0], addr(4000 + c));
        }
        let out = feed(&mut job, &poll, addr(4000));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.kind, WireKind::Gia);
    }

    fn stat(counter: &std::sync::atomic::AtomicU64) -> u64 {
        counter.load(std::sync::atomic::Ordering::Relaxed)
    }

    #[test]
    fn join_rejects_specs_exceeding_host_budget() {
        // d = u32::MAX would pin gigabytes of host counters per live
        // round; the default budget refuses the spec outright.
        let mut job = Job::new(3, profile(1 << 20), Arc::new(ServerStats::default()));
        let huge = mkspec(u32::MAX, 2, 1, 256);
        let out = feed(&mut job, &join_frame(3, 0, &huge), addr(4100));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_BAD_SPEC);
        assert!(!job.is_configured());

        // A tighter configured budget rejects a spec the default accepts.
        let spec = mkspec(10_000, 2, 1, 8);
        let limits = JobLimits { host_bytes: 1 << 10, ..JobLimits::default() };
        let mut tight =
            Job::with_limits(4, profile(1 << 20), limits, Arc::new(ServerStats::default()));
        let out = feed(&mut tight, &join_frame(4, 0, &spec), addr(4101));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_BAD_SPEC);
        let mut roomy = Job::new(5, profile(1 << 20), Arc::new(ServerStats::default()));
        let out = feed(&mut roomy, &join_frame(5, 0, &spec), addr(4102));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
    }

    #[test]
    fn spill_is_deduped_and_capped() {
        // One resident 64-dim block (200 B of registers), a 40-block vote
        // space, and a spill limit that clamps to MIN_SPILL_ENTRIES = 16.
        let spec = mkspec(64 * 40, 2, 2, 8);
        let stats = Arc::new(ServerStats::default());
        let limits = JobLimits { spill_bytes: 1, ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(200), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let v = BitVec::from_indices(spec.d as usize, &[1]);
        let frames = vote_frames(9, 0, 0, &v, &spec);
        // Blocks 1..=20 are all beyond the (stalled-at-0) window: the
        // first 16 spill, the rest are dropped at the cap.
        for f in &frames[1..=20] {
            assert!(feed(&mut job, f, addr(4000)).is_empty());
        }
        assert_eq!(stat(&stats.spilled), 16);
        assert_eq!(stat(&stats.spill_dropped), 4);
        // Retransmitting a spilled block is deduped, not re-buffered.
        feed(&mut job, &frames[1], addr(4000));
        assert_eq!(stat(&stats.spilled), 16);
        assert_eq!(stat(&stats.duplicates), 1);
    }

    #[test]
    fn reserve_budget_bounds_reflection() {
        let spec = mkspec(64, 2, 1, 8);
        let stats = Arc::new(ServerStats::default());
        let limits = JobLimits { reserve_budget: 2, ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let v = BitVec::from_indices(64, &[1, 2]);
        for c in 0..2u16 {
            feed(&mut job, &vote_frames(9, c, 0, &v, &spec)[0], addr(4000 + c));
        }
        assert!(job.round_gia(0).is_some());
        // Retransmitted data frames after completion reflect nothing.
        let replay = &vote_frames(9, 0, 0, &v, &spec)[0];
        assert!(feed(&mut job, replay, addr(6666)).is_empty());
        let poll_from = |job: &mut Job, source: SocketAddr| {
            let poll = encode_frame(
                &Header {
                    kind: WireKind::Poll,
                    client: 0,
                    job: 9,
                    round: 0,
                    block: 0,
                    n_blocks: 0,
                    elems: 0,
                    aux: WireKind::Gia as u32,
                },
                &[],
            );
            feed(job, &poll, source)
        };
        // A spoofed source is served the full GIA set only
        // `reserve_budget` times, then nothing.
        let spoof = addr(6666);
        assert!(!poll_from(&mut job, spoof).is_empty());
        assert!(!poll_from(&mut job, spoof).is_empty());
        assert!(poll_from(&mut job, spoof).is_empty());
        assert!(poll_from(&mut job, spoof).is_empty());
        assert_eq!(stat(&stats.reserves_suppressed), 2);
        // Filling the source table with spoofed addresses must not lock
        // out the Join-registered clients.
        for port in 0..(MAX_RESERVE_SOURCES as u16 + 8) {
            poll_from(&mut job, addr(10_000 + port));
        }
        assert!(stat(&stats.reserves_suppressed) > 2, "table never filled");
        assert!(!poll_from(&mut job, addr(4000)).is_empty());
        assert!(!poll_from(&mut job, addr(4001)).is_empty());
    }

    #[test]
    fn empty_consensus_closes_round_and_multicasts_empty_aggregate() {
        // N = 2, a = 2, disjoint votes: nothing passes the threshold.
        // The round must close at phase 1 (no wedged live-round slot) and
        // the completion multicast must answer the clients' aggregate
        // wait too — one zero-lane block, the phase-completion signal
        // `wire::update_chunks` defines.
        let spec = mkspec(64, 2, 2, 8);
        let mut job = make_job(&spec, 1 << 20);
        let v0 = BitVec::from_indices(64, &[1, 2]);
        let v1 = BitVec::from_indices(64, &[10, 20]);
        assert!(feed(&mut job, &vote_frames(9, 0, 0, &v0, &spec)[0], addr(4000)).is_empty());
        let out = feed(&mut job, &vote_frames(9, 1, 0, &v1, &spec)[0], addr(4001));
        let kinds: Vec<WireKind> =
            out.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
        assert!(kinds.contains(&WireKind::Gia), "no GIA in completion multicast");
        assert!(kinds.contains(&WireKind::Aggregate), "empty aggregate not multicast");
        assert_eq!(job.round_gia(0).unwrap().count_ones(), 0);
        assert_eq!(job.round_aggregate(0), Some(&[][..]), "round did not close");
        assert_eq!(job.stats.rounds_completed.load(std::sync::atomic::Ordering::Relaxed), 1);
        let agg = out
            .iter()
            .map(|(b, _)| decode_frame(b).unwrap())
            .find(|f| f.header.kind == WireKind::Aggregate)
            .unwrap();
        assert_eq!((agg.header.n_blocks, agg.header.elems, agg.header.aux), (1, 0, 0));
        assert!(agg.payload.is_empty());
    }

    #[test]
    fn non_finite_vote_aux_is_rejected_at_ingest() {
        let spec = mkspec(64, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        let v = BitVec::from_indices(64, &[1, 2]);
        // A NaN local-max would make global_max (and every client's f)
        // NaN; the whole frame is rejected, vote bits included.
        let (dims, bytes) = &vote_chunks(&v, 8)[0];
        let evil = encode_frame(
            &Header {
                kind: WireKind::Vote,
                client: 0,
                job: 9,
                round: 0,
                block: 0,
                n_blocks: 1,
                elems: *dims as u32,
                aux: f32::NAN.to_bits(),
            },
            bytes,
        );
        assert!(feed(&mut job, &evil, addr(4000)).is_empty());
        assert_eq!(job.stats.non_finite_aux.load(std::sync::atomic::Ordering::Relaxed), 1);
        // Finite-aux frames complete the round with a clean global max.
        for c in 0..2u16 {
            feed(&mut job, &vote_frames(9, c, 0, &v, &spec)[0], addr(4000 + c));
        }
        let poll = encode_frame(
            &Header {
                kind: WireKind::Poll,
                client: 0,
                job: 9,
                round: 0,
                block: 0,
                n_blocks: 0,
                elems: 0,
                aux: WireKind::Gia as u32,
            },
            &[],
        );
        let out = feed(&mut job, &poll, addr(4000));
        let gia = decode_frame(&out[0].0).unwrap();
        assert_eq!(gia.header.kind, WireKind::Gia);
        let m = f32::from_bits(gia.header.aux);
        assert!(m.is_finite(), "NaN leaked into the folded global max");
        assert_eq!(m, 1.0, "helper frames carry local max 1.0");
    }

    #[test]
    fn downlink_kind_frames_get_no_reply() {
        // Unconfigured job: a forged Gia must not even earn the
        // JoinAck/UNKNOWN nudge (reflection damping).
        let stats = Arc::new(ServerStats::default());
        let mut fresh = Job::new(2, profile(1 << 20), Arc::clone(&stats));
        let forged = |kind: WireKind, job: u32| {
            encode_frame(
                &Header {
                    kind,
                    client: u16::MAX,
                    job,
                    round: 0,
                    block: 0,
                    n_blocks: 1,
                    elems: 0,
                    aux: 0,
                },
                &[],
            )
        };
        assert!(feed(&mut fresh, &forged(WireKind::Gia, 2), addr(7000)).is_empty());
        assert!(feed(&mut fresh, &forged(WireKind::JoinAck, 2), addr(7000)).is_empty());
        assert_eq!(stat(&stats.downlink_spoofs), 2);
        // Configured job: same silence.
        let spec = mkspec(64, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        assert!(feed(&mut job, &forged(WireKind::Aggregate, 9), addr(7000)).is_empty());
        assert!(feed(&mut job, &forged(WireKind::NotReady, 9), addr(7000)).is_empty());
        assert_eq!(job.stats.downlink_spoofs.load(std::sync::atomic::Ordering::Relaxed), 2);
    }

    #[test]
    fn timer_drives_idle_reclamation_without_traffic() {
        // Sans-I/O discipline: after a round stalls with a resident
        // aggregator, `handle` arms a timer; `on_tick` at that deadline
        // reclaims the registers with NO further traffic (the busy-wake
        // fix — backends sleep until the deadline instead of polling).
        let spec = mkspec(100, 2, 2, 8);
        let stats = Arc::new(ServerStats::default());
        let limits =
            JobLimits { idle_release_after: Duration::from_millis(50), ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(200), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let v = BitVec::from_indices(100, &[1, 50, 80]);
        let t0 = Instant::now();
        let datagram = vote_frames(9, 0, 0, &v, &spec)[0].clone();
        let frame = decode_frame(&datagram).unwrap();
        let out = job.handle(&frame, addr(4000), t0);
        let deadline = out.timer.expect("resident aggregator must arm the idle timer");
        assert_eq!(deadline, t0 + Duration::from_millis(50));
        assert!(job.state.as_ref().unwrap().registers.used() > 0);
        // Before the deadline a tick is a no-op and the timer stays armed.
        let out = job.on_tick(t0 + Duration::from_millis(10));
        assert!(out.frames.is_empty());
        assert!(out.timer.is_some());
        assert!(job.state.as_ref().unwrap().registers.used() > 0);
        // At the deadline the registers come back and the timer disarms.
        let out = job.on_tick(deadline);
        assert!(out.timer.is_none(), "quiescent job must not ask for wakeups");
        assert_eq!(job.state.as_ref().unwrap().registers.used(), 0);
        assert_eq!(stat(&stats.idle_releases), 1);
    }

    #[test]
    fn shared_budget_is_global_per_tenant_across_daemons() {
        // Two Jobs with the same id (= one tenant hosted by two shard
        // daemons) draw on ONE accountant: the second configure is
        // refused once the tenant's budget is spent, an idempotent
        // re-join does not double-charge, another tenant is unaffected,
        // and dropping a job hands its reservation back.
        let spec = mkspec(10_000, 2, 1, 8);
        let worst = spec.host_bytes_per_round() * MAX_LIVE_ROUNDS;
        let limits = JobLimits { host_bytes: worst + worst / 2, ..JobLimits::default() };
        let budget = Arc::new(HostBudget::new(limits.host_bytes));
        let mk = |id: u32| {
            Job::with_budget(
                id,
                profile(1 << 20),
                limits,
                Arc::clone(&budget),
                Arc::new(ServerStats::default()),
            )
        };
        let mut shard0 = mk(4);
        let mut shard1 = mk(4);
        let out = feed(&mut shard0, &join_frame(4, 0, &spec), addr(4700));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
        let out = feed(&mut shard1, &join_frame(4, 0, &spec), addr(4701));
        assert_eq!(
            decode_frame(&out[0].0).unwrap().header.aux,
            JOIN_BAD_SPEC,
            "second shard configure must see the tenant's budget spent"
        );
        // Re-joining the configured shard is idempotent (no extra charge).
        let out = feed(&mut shard0, &join_frame(4, 1, &spec), addr(4702));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
        // A different tenant has its own tally under the same accountant.
        let mut other = mk(5);
        let out = feed(&mut other, &join_frame(5, 0, &spec), addr(4703));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
        // Retiring the first shard's job releases the tenant's bytes.
        drop(shard0);
        let out = feed(&mut shard1, &join_frame(4, 0, &spec), addr(4701));
        assert_eq!(decode_frame(&out[0].0).unwrap().header.aux, JOIN_OK);
    }

    #[test]
    fn steady_state_rounds_emit_from_the_pool() {
        // Round 0 warms the frame pool (misses allowed); every later
        // round must emit entirely from recycled buffers — the
        // allocation-free steady state the backends get by calling
        // `recycle` after each transmit.
        let spec = mkspec(256, 2, 1, 8);
        let mut job = make_job(&spec, 1 << 20);
        let run_round = |job: &mut Job, round: u32| {
            let votes = BitVec::from_indices(256, &[1, 7, 100]);
            for c in 0..2u16 {
                for f in vote_frames(9, c, round, &votes, &spec) {
                    let frame = decode_frame(&f).unwrap();
                    let out = job.handle(&frame, addr(4000 + c), Instant::now());
                    job.recycle(out.frames);
                }
            }
            let k_s = job.round_gia(round).unwrap().count_ones();
            let lanes: Vec<i32> = (0..k_s as i32).collect();
            for c in 0..2u16 {
                for f in update_frames(9, c, round, &lanes, &spec) {
                    let frame = decode_frame(&f).unwrap();
                    let out = job.handle(&frame, addr(4000 + c), Instant::now());
                    job.recycle(out.frames);
                }
            }
            assert!(job.round_aggregate(round).is_some(), "round {round} incomplete");
        };
        run_round(&mut job, 0);
        let warmup_misses = stat(&job.stats.pool_misses);
        assert!(warmup_misses > 0, "warm-up must populate the pool");
        for r in 1..4 {
            run_round(&mut job, r);
        }
        assert_eq!(
            stat(&job.stats.pool_misses),
            warmup_misses,
            "steady-state rounds allocated fresh frame buffers"
        );
        assert!(stat(&job.stats.frames_pooled) > 0, "pool never served a frame");
    }

    #[test]
    fn quorum_deadline_closes_both_phases_without_the_dead_client() {
        // N = 3, Q = 2, a = 1: clients 0 and 1 deliver both phases;
        // client 2 is dead. Each phase must close exactly at its quorum
        // deadline via `on_tick`, with the aggregate bit-exact over the
        // two survivors, and the dead client's late vote afterwards must
        // only move `late_after_close`.
        let spec = JobSpec { quorum: 2, ..mkspec(64, 3, 1, 8) };
        let stats = Arc::new(ServerStats::default());
        let limits =
            JobLimits { phase_deadline: Duration::from_millis(40), ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let t0 = Instant::now();
        let votes: Vec<BitVec> =
            (0..2).map(|c| BitVec::from_indices(64, &[c, 7, 30])).collect();
        for (c, v) in votes.iter().enumerate() {
            let f = vote_frames(9, c as u16, 1, v, &spec).remove(0);
            let out = job.handle(&decode_frame(&f).unwrap(), addr(4000 + c as u16), t0);
            assert!(out.frames.is_empty(), "phase must stay open before the deadline");
        }
        // Quorum met ⇒ the timer demands a wakeup at exactly t0 + 40 ms.
        let deadline = job.next_timer().expect("quorum met must arm the phase deadline");
        assert_eq!(deadline, t0 + Duration::from_millis(40));
        let out = job.on_tick(deadline);
        let kinds: Vec<WireKind> =
            out.frames.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
        assert!(kinds.contains(&WireKind::Gia), "deadline tick must multicast the GIA");
        assert_eq!(stat(&stats.quorum_closes), 1);
        assert_eq!(job.round_gia(1), Some(&deduce_gia(&votes, 1)));
        let k_s = job.round_gia(1).unwrap().count_ones();

        // Phase 2: survivors upload; dead client still silent. The phase
        // deadline arms from the first Update frame.
        let t1 = t0 + Duration::from_millis(60);
        let lanes: Vec<Vec<i32>> = (0..2)
            .map(|c| (0..k_s as i32).map(|x| (c + 1) as i32 * x).collect())
            .collect();
        for (c, l) in lanes.iter().enumerate() {
            for f in update_frames(9, c as u16, 1, l, &spec) {
                job.handle(&decode_frame(&f).unwrap(), addr(4000 + c as u16), t1);
            }
        }
        assert!(job.round_aggregate(1).is_none(), "round must stay open until the deadline");
        let deadline = job.next_timer().expect("phase-2 quorum must arm its deadline");
        assert_eq!(deadline, t1 + Duration::from_millis(40));
        let out = job.on_tick(deadline);
        let kinds: Vec<WireKind> =
            out.frames.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
        assert!(kinds.contains(&WireKind::Aggregate), "deadline tick must multicast the sum");
        assert_eq!(stat(&stats.quorum_closes), 2);
        let want: Vec<i32> = (0..k_s as i32).map(|x| 3 * x).collect();
        assert_eq!(job.round_aggregate(1), Some(&want[..]), "survivor sum must be bit-exact");
        // Registers fully reclaimed on the forced close.
        assert_eq!(job.state.as_ref().unwrap().registers.used(), 0);

        // The dead client wakes up late: counted, dropped, nothing else.
        let late = vote_frames(9, 2, 1, &votes[0], &spec).remove(0);
        let out = job.handle(&decode_frame(&late).unwrap(), addr(4002), deadline);
        assert!(out.frames.is_empty());
        assert_eq!(stat(&stats.late_after_close), 1);
        assert_eq!(job.round_aggregate(1), Some(&want[..]), "late frame corrupted the sum");
    }

    #[test]
    fn quorum_needs_deadline_and_deadline_needs_quorum() {
        // Q = 2 of 3. Before the deadline a met quorum must not close the
        // phase; past the deadline an unmet quorum must not either — but
        // the first frame that completes the quorum after the deadline
        // closes it inline, with no tick in between.
        let spec = JobSpec { quorum: 2, ..mkspec(64, 3, 1, 8) };
        let stats = Arc::new(ServerStats::default());
        let limits =
            JobLimits { phase_deadline: Duration::from_millis(40), ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let t0 = Instant::now();
        let v = BitVec::from_indices(64, &[3, 9]);
        let f0 = vote_frames(9, 0, 0, &v, &spec).remove(0);
        job.handle(&decode_frame(&f0).unwrap(), addr(4000), t0);
        // One vote in: past-deadline ticks are no-ops (quorum unmet), and
        // no quorum timer is armed (only the idle-reclaim one).
        let out = job.on_tick(t0 + Duration::from_millis(200));
        assert!(out.frames.is_empty());
        assert_eq!(stat(&stats.quorum_closes), 0);
        assert!(job.round_gia(0).is_none());
        // The second vote lands after the deadline: closes inline.
        let f1 = vote_frames(9, 1, 0, &v, &spec).remove(0);
        let out = job.handle(&decode_frame(&f1).unwrap(), addr(4001), t0 + Duration::from_millis(210));
        let kinds: Vec<WireKind> =
            out.frames.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
        assert!(kinds.contains(&WireKind::Gia), "late quorum completion must close inline");
        assert_eq!(stat(&stats.quorum_closes), 1);
        assert_eq!(job.round_gia(0), Some(&deduce_gia(&[v.clone(), v], 1)));
    }

    #[test]
    fn idle_rounds_release_their_registers() {
        // 200 B of registers hold exactly one 64-dim vote wave, so two
        // in-progress rounds contend for the whole register file.
        let spec = mkspec(100, 2, 2, 8);
        let stats = Arc::new(ServerStats::default());
        let limits = JobLimits { idle_release_after: Duration::ZERO, ..JobLimits::default() };
        let mut job = Job::with_limits(9, profile(200), limits, Arc::clone(&stats));
        for c in 0..spec.n_clients {
            feed(&mut job, &join_frame(9, c, &spec), addr(4000 + c));
        }
        let votes: Vec<BitVec> = (0..2).map(|c| BitVec::from_indices(100, &[c, 40, 80])).collect();
        let mk = |c: u16, round: u32| vote_frames(9, c, round, &votes[c as usize], &spec);

        // Round 0: one contribution allocates the only wave, then stalls.
        feed(&mut job, &mk(0, 0)[0], addr(4000));
        assert!(job.state.as_ref().unwrap().registers.used() > 0);
        // Round 1 traffic reclaims round 0's idle aggregator instead of
        // spilling behind it forever, and completes normally.
        feed(&mut job, &mk(0, 1)[0], addr(4000));
        assert!(stat(&stats.idle_releases) >= 1);
        feed(&mut job, &mk(0, 1)[1], addr(4000));
        feed(&mut job, &mk(1, 1)[0], addr(4001));
        let out = feed(&mut job, &mk(1, 1)[1], addr(4001));
        assert!(!out.is_empty(), "round 1 should finish phase 1");
        assert_eq!(job.round_gia(1), Some(&deduce_gia(&votes, 2)));

        // Round 0 stays live: retransmission rebuilds the reclaimed wave
        // from scratch and the round still aggregates correctly.
        for c in 0..2u16 {
            for f in &mk(c, 0) {
                feed(&mut job, f, addr(4000 + c));
            }
        }
        assert_eq!(job.round_gia(0), Some(&deduce_gia(&votes, 2)));
    }
}
