//! The switch daemon: a UDP aggregation server hosting multiple
//! concurrent FL jobs (multi-tenant), each job running FediAC's two-phase
//! protocol over the [`crate::wire`] format.
//!
//! Architecture (sans-I/O core + pluggable I/O backends, DESIGN.md §6):
//!
//! * [`job`] — the per-job protocol state machine, **sans-I/O**: it owns
//!   no socket and reads no clock. Inputs are decoded frames plus the
//!   caller's `now` ([`Job::handle`]) or timer expiries ([`Job::on_tick`]);
//!   outputs are a [`job::JobOutput`] — datagrams to transmit and the
//!   next deadline to wake at. Per-round vote counters and update
//!   accumulators are backed by the existing
//!   [`crate::switch::RegisterFile`] byte accounting. When a phase's
//!   register demand exceeds the [`crate::configx::PsProfile`] capacity
//!   the block space is processed in *waves*: only a window of blocks is
//!   resident in registers, packets beyond it spill to host memory, and
//!   retired waves copy their partial aggregates out — §III-B's memory
//!   pressure made operational. Duplicate suppression reuses the
//!   [`crate::switch::Scoreboard`] inside the wave aggregators.
//! * [`daemon`] — the front door ([`ServeOptions`], [`serve`],
//!   [`serve_sharded`]) plus the frame-routing/admission rules both
//!   backends share ([`crate::wire::peek_route`], the job cap, the
//!   unknown-job `JoinAck`).
//! * [`threaded`] — the thread-per-job backend: one dispatch thread
//!   routes datagrams to per-job worker threads over channels. Jobs are
//!   concurrent with each other and serialized internally.
//! * [`reactor`] — the single-thread backend: a nonblocking socket, a
//!   readiness poll ([`crate::net::poll`]) and a coarse timer wheel
//!   drive *every* job from one thread — zero per-job threads or
//!   channels, the switch-class resource discipline the paper assumes.
//! * [`fleet`] — the multi-core backend: N reactor cores, each owning a
//!   member socket of one `SO_REUSEPORT` group on the shared port, with
//!   jobs partitioned across cores by a `job_id` hash
//!   ([`fleet::owner_core`]) so every job's state stays core-local.
//!   Kernel REUSEPORT steering is per-flow, not per-job, so cores
//!   forward misdirected datagrams to the owner core
//!   ([`ServerStats::steered_frames`]) over per-core inboxes.
//!
//! Backend choice is wire-invisible: all drive the same [`Job`] state
//! machine, so their GIA/aggregate outputs are bit-identical
//! (`tests/wire_backend.rs` enforces this against the simulator too).

pub mod daemon;
pub mod fleet;
pub mod job;
pub mod reactor;
pub mod threaded;

pub use daemon::{serve, serve_sharded, IoBackend, ServeOptions, ServerHandle};
pub use job::{
    Job, JobLimits, JobOutput, RoundTiming, JOIN_BAD_SPEC, JOIN_OK, JOIN_SPEC_MISMATCH,
    JOIN_UNKNOWN_JOB,
};

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::telemetry::{Hist, HistSummary};

/// How a [`HostBudget`] arbitrates the shared cap between tenants.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum BudgetMode {
    /// Each tenant may reserve up to the whole cap — whoever asks first
    /// wins, and a single tenant can starve every later arrival.
    #[default]
    FirstCome,
    /// Equal split across *live* tenants (current holders plus the
    /// requester), DSLab-style throughput sharing: with L live tenants no
    /// single tenant may hold more than `cap / L`, and the sum of all
    /// reservations is additionally bounded by the cap (holders admitted
    /// under a smaller L keep what they hold — the split only governs new
    /// reservations). Work-conserving: a lone tenant still gets the full
    /// cap. The fleet backend defaults to this mode so many tenants
    /// landing on many cores cannot be starved first-come.
    FairShare,
}

/// Host-memory accountant: per-tenant (job-id-keyed) byte reservations
/// against one cap. Each daemon normally owns a private accountant, but
/// [`serve_sharded`] hands one `Arc<HostBudget>` to every shard daemon
/// of a deployment so a tenant's [`JobLimits::host_bytes`] bounds its
/// footprint across the *whole* shard set — previously each shard
/// enforced the budget independently, quietly multiplying it by N. The
/// fleet backend shares one accountant across all its cores the same
/// way, in [`BudgetMode::FairShare`] by default.
#[derive(Debug)]
pub struct HostBudget {
    cap: usize,
    mode: BudgetMode,
    by_job: Mutex<HashMap<u32, usize>>,
}

impl HostBudget {
    /// Accountant allowing up to `cap` bytes per tenant (first-come).
    pub fn new(cap: usize) -> Self {
        HostBudget { cap, mode: BudgetMode::FirstCome, by_job: Mutex::new(HashMap::new()) }
    }

    /// Accountant splitting `cap` equally across live tenants
    /// ([`BudgetMode::FairShare`]).
    pub fn new_fair(cap: usize) -> Self {
        HostBudget { cap, mode: BudgetMode::FairShare, by_job: Mutex::new(HashMap::new()) }
    }

    /// The per-tenant byte cap.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// The arbitration mode this accountant was built with.
    pub fn mode(&self) -> BudgetMode {
        self.mode
    }

    /// Bytes currently reserved by tenant `job`.
    pub fn reserved(&self, job: u32) -> usize {
        self.by_job.lock().unwrap().get(&job).copied().unwrap_or(0)
    }

    /// Reserve `bytes` for tenant `job`; false when the reservation would
    /// break the arbitration rule (nothing is charged then). Under
    /// [`BudgetMode::FirstCome`] the only rule is the tenant's own total
    /// ≤ cap; under [`BudgetMode::FairShare`] the tenant's total must
    /// also fit its equal share `cap / live` (live = current holders
    /// plus this requester) and the sum over all tenants must fit the
    /// cap. A refused or zero-byte reservation leaves no map entry
    /// behind — unauthenticated Join sprays with over-budget specs must
    /// not grow this table.
    pub fn try_reserve(&self, job: u32, bytes: usize) -> bool {
        let mut m = self.by_job.lock().unwrap();
        let cur = m.get(&job).copied().unwrap_or(0);
        let Some(total) = cur.checked_add(bytes) else {
            return false;
        };
        let allowed = match self.mode {
            BudgetMode::FirstCome => total <= self.cap,
            BudgetMode::FairShare => {
                let live = m.len() + usize::from(!m.contains_key(&job));
                let grand_total: usize = m.values().sum::<usize>().saturating_add(bytes);
                total <= self.cap / live.max(1) && grand_total <= self.cap
            }
        };
        if allowed {
            if total > 0 {
                m.insert(job, total);
            }
            true
        } else {
            false
        }
    }

    /// Return `bytes` of tenant `job`'s reservation.
    pub fn release(&self, job: u32, bytes: usize) {
        let mut m = self.by_job.lock().unwrap();
        if let Some(cur) = m.get_mut(&job) {
            *cur = cur.saturating_sub(bytes);
            if *cur == 0 {
                m.remove(&job);
            }
        }
    }
}

/// Cross-thread daemon counters (lock-free; workers update directly).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Datagrams received by the dispatch loop (valid or not).
    pub packets: AtomicU64,
    /// Frames dropped for malformed bytes or impossible geometry
    /// (bad route peek, failed decode, out-of-range block/elems/client).
    pub decode_errors: AtomicU64,
    /// Frames dropped as already-seen contributions (scoreboard hits,
    /// stale-block replays, re-buffered spill, post-completion data).
    pub duplicates: AtomicU64,
    /// Data blocks buffered to host memory because they landed beyond
    /// the resident register wave.
    pub spilled: AtomicU64,
    /// Spill entries dropped at the per-round cap (repaired by client
    /// retransmission once the wave advances).
    pub spill_dropped: AtomicU64,
    /// Register waves advanced past the first (each bump = one wave
    /// retired and the window moved, §III-B memory pressure).
    pub waves: AtomicU64,
    /// Aggregate lanes that saturated i32 during accumulation.
    pub overflow_lanes: AtomicU64,
    /// Wave allocations refused for lack of register memory (the round
    /// keeps spilling until another wave releases).
    pub register_stalls: AtomicU64,
    /// Full GIA/aggregate re-serves refused by the per-source budget
    /// (UDP reflection damping).
    pub reserves_suppressed: AtomicU64,
    /// Register aggregators reclaimed from rounds with no recent traffic.
    pub idle_releases: AtomicU64,
    /// Server-bound frames of downlink-only kinds (Gia / Aggregate /
    /// JoinAck / NotReady) dropped without a reply (anti-reflection).
    pub downlink_spoofs: AtomicU64,
    /// Vote frames rejected because their local-max aux was NaN/Inf
    /// (would poison the job-wide scale factor).
    pub non_finite_aux: AtomicU64,
    /// Join frames accepted (including idempotent re-joins).
    pub joins: AtomicU64,
    /// Jobs configured by a first valid Join.
    pub jobs_created: AtomicU64,
    /// Datagrams dropped because the per-daemon job cap was reached.
    pub jobs_rejected: AtomicU64,
    /// Rounds whose phase-2 aggregate completed (or closed empty).
    pub rounds_completed: AtomicU64,
    /// Worker threads spawned by the threaded backend. The reactor
    /// backend never bumps this — one thread serves every job
    /// (`tests/wire_backend.rs` asserts zero per-job spawns through it).
    pub workers_spawned: AtomicU64,
    /// Backend wakeups driven by a [`Job`] timer deadline rather than by
    /// traffic (idle register reclamation). The busy-wake regression
    /// guard: an idle daemon must not accumulate these, because backends
    /// sleep until the job's own deadline instead of polling on a fixed
    /// tick.
    pub idle_wakeups: AtomicU64,
    /// Outgoing frame buffers served from the per-job
    /// [`crate::wire::FrameScratch`] pool (recycled, no allocation).
    pub frames_pooled: AtomicU64,
    /// Outgoing frame buffers freshly allocated because the pool was
    /// empty. Grows during warm-up only: steady-state rounds must hold
    /// this flat (`fediac bench-codec` / `bench-wire` assert it).
    pub pool_misses: AtomicU64,
    /// Datagrams that landed on a non-owner fleet core (kernel
    /// `SO_REUSEPORT` steering is per-flow, not per-job) and were
    /// forwarded to their job's owner core. Always zero for the
    /// single-socket backends.
    pub steered_frames: AtomicU64,
    /// Phases force-closed at their deadline with the quorum met but
    /// fewer than all N clients complete (PROTOCOL.md §11). Always zero
    /// for quorum-disabled (Q = 0) jobs.
    pub quorum_closes: AtomicU64,
    /// Straggler data frames arriving after their phase closed (quorum
    /// close or normal completion); dropped without touching the
    /// consensus bitmap or the aggregate.
    pub late_after_close: AtomicU64,
    /// End-to-end round latency (first data frame of the round to the
    /// aggregate multicast), microseconds.
    pub hist_round_latency: Hist,
    /// Vote-phase duration (first data frame to the GIA multicast),
    /// microseconds.
    pub hist_vote_phase: Hist,
    /// Update-phase duration (GIA multicast to the aggregate multicast),
    /// microseconds.
    pub hist_update_phase: Hist,
    /// Register-stall duration: how long a round's wave allocation kept
    /// being refused before registers freed up, microseconds.
    pub hist_register_stall: Hist,
    /// Straggler gap: how long a completing phase sat one contribution
    /// short waiting for its final data frame, microseconds.
    pub hist_straggler_gap: Hist,
}

/// Point-in-time copy of [`ServerStats`] for reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// See [`ServerStats::packets`].
    pub packets: u64,
    /// See [`ServerStats::decode_errors`].
    pub decode_errors: u64,
    /// See [`ServerStats::duplicates`].
    pub duplicates: u64,
    /// See [`ServerStats::spilled`].
    pub spilled: u64,
    /// See [`ServerStats::spill_dropped`].
    pub spill_dropped: u64,
    /// See [`ServerStats::waves`].
    pub waves: u64,
    /// See [`ServerStats::overflow_lanes`].
    pub overflow_lanes: u64,
    /// See [`ServerStats::register_stalls`].
    pub register_stalls: u64,
    /// See [`ServerStats::reserves_suppressed`].
    pub reserves_suppressed: u64,
    /// See [`ServerStats::idle_releases`].
    pub idle_releases: u64,
    /// See [`ServerStats::downlink_spoofs`].
    pub downlink_spoofs: u64,
    /// See [`ServerStats::non_finite_aux`].
    pub non_finite_aux: u64,
    /// See [`ServerStats::joins`].
    pub joins: u64,
    /// See [`ServerStats::jobs_created`].
    pub jobs_created: u64,
    /// See [`ServerStats::jobs_rejected`].
    pub jobs_rejected: u64,
    /// See [`ServerStats::rounds_completed`].
    pub rounds_completed: u64,
    /// See [`ServerStats::workers_spawned`].
    pub workers_spawned: u64,
    /// See [`ServerStats::idle_wakeups`].
    pub idle_wakeups: u64,
    /// See [`ServerStats::frames_pooled`].
    pub frames_pooled: u64,
    /// See [`ServerStats::pool_misses`].
    pub pool_misses: u64,
    /// See [`ServerStats::steered_frames`].
    pub steered_frames: u64,
    /// See [`ServerStats::quorum_closes`].
    pub quorum_closes: u64,
    /// See [`ServerStats::late_after_close`].
    pub late_after_close: u64,
    /// See [`ServerStats::hist_round_latency`].
    pub hist_round_latency: HistSummary,
    /// See [`ServerStats::hist_vote_phase`].
    pub hist_vote_phase: HistSummary,
    /// See [`ServerStats::hist_update_phase`].
    pub hist_update_phase: HistSummary,
    /// See [`ServerStats::hist_register_stall`].
    pub hist_register_stall: HistSummary,
    /// See [`ServerStats::hist_straggler_gap`].
    pub hist_straggler_gap: HistSummary,
}

impl StatsSnapshot {
    /// Fold another daemon's counters in — the single place that knows
    /// every field, so multi-shard aggregation (the shard-aware wire
    /// bench) cannot silently drop a counter added later.
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.packets += other.packets;
        self.decode_errors += other.decode_errors;
        self.duplicates += other.duplicates;
        self.spilled += other.spilled;
        self.spill_dropped += other.spill_dropped;
        self.waves += other.waves;
        self.overflow_lanes += other.overflow_lanes;
        self.register_stalls += other.register_stalls;
        self.reserves_suppressed += other.reserves_suppressed;
        self.idle_releases += other.idle_releases;
        self.downlink_spoofs += other.downlink_spoofs;
        self.non_finite_aux += other.non_finite_aux;
        self.joins += other.joins;
        self.jobs_created += other.jobs_created;
        self.jobs_rejected += other.jobs_rejected;
        self.rounds_completed += other.rounds_completed;
        self.workers_spawned += other.workers_spawned;
        self.idle_wakeups += other.idle_wakeups;
        self.frames_pooled += other.frames_pooled;
        self.pool_misses += other.pool_misses;
        self.steered_frames += other.steered_frames;
        self.quorum_closes += other.quorum_closes;
        self.late_after_close += other.late_after_close;
        self.hist_round_latency.merge(&other.hist_round_latency);
        self.hist_vote_phase.merge(&other.hist_vote_phase);
        self.hist_update_phase.merge(&other.hist_update_phase);
        self.hist_register_stall.merge(&other.hist_register_stall);
        self.hist_straggler_gap.merge(&other.hist_straggler_gap);
    }

    /// Render one JSON object (a single line, no trailing newline) with
    /// every counter plus p50/p90/p99/max summaries of each latency
    /// histogram — the payload of `fediac serve --metrics-interval`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        let mut counter = |k: &str, v: u64| {
            let _ = write!(out, "\"{k}\":{v},");
        };
        counter("packets", self.packets);
        counter("decode_errors", self.decode_errors);
        counter("duplicates", self.duplicates);
        counter("spilled", self.spilled);
        counter("spill_dropped", self.spill_dropped);
        counter("waves", self.waves);
        counter("overflow_lanes", self.overflow_lanes);
        counter("register_stalls", self.register_stalls);
        counter("reserves_suppressed", self.reserves_suppressed);
        counter("idle_releases", self.idle_releases);
        counter("downlink_spoofs", self.downlink_spoofs);
        counter("non_finite_aux", self.non_finite_aux);
        counter("joins", self.joins);
        counter("jobs_created", self.jobs_created);
        counter("jobs_rejected", self.jobs_rejected);
        counter("rounds_completed", self.rounds_completed);
        counter("workers_spawned", self.workers_spawned);
        counter("idle_wakeups", self.idle_wakeups);
        counter("frames_pooled", self.frames_pooled);
        counter("pool_misses", self.pool_misses);
        counter("steered_frames", self.steered_frames);
        counter("quorum_closes", self.quorum_closes);
        counter("late_after_close", self.late_after_close);
        for (key, h) in [
            ("round_latency_us", &self.hist_round_latency),
            ("vote_phase_us", &self.hist_vote_phase),
            ("update_phase_us", &self.hist_update_phase),
            ("register_stall_us", &self.hist_register_stall),
            ("straggler_gap_us", &self.hist_straggler_gap),
        ] {
            let _ = write!(
                out,
                "\"{key}\":{{\"count\":{},\"p50\":{},\"p90\":{},\"p99\":{},\"max\":{}}},",
                h.count(),
                h.quantile(0.50),
                h.quantile(0.90),
                h.quantile(0.99),
                h.max
            );
        }
        out.pop(); // trailing comma
        out.push('}');
        out
    }
}

impl ServerStats {
    /// Increment one counter (relaxed; counters are advisory).
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n` to one counter (relaxed).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Copy every counter at one point in time.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            spill_dropped: self.spill_dropped.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            overflow_lanes: self.overflow_lanes.load(Ordering::Relaxed),
            register_stalls: self.register_stalls.load(Ordering::Relaxed),
            reserves_suppressed: self.reserves_suppressed.load(Ordering::Relaxed),
            idle_releases: self.idle_releases.load(Ordering::Relaxed),
            downlink_spoofs: self.downlink_spoofs.load(Ordering::Relaxed),
            non_finite_aux: self.non_finite_aux.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            jobs_created: self.jobs_created.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            rounds_completed: self.rounds_completed.load(Ordering::Relaxed),
            workers_spawned: self.workers_spawned.load(Ordering::Relaxed),
            idle_wakeups: self.idle_wakeups.load(Ordering::Relaxed),
            frames_pooled: self.frames_pooled.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            steered_frames: self.steered_frames.load(Ordering::Relaxed),
            quorum_closes: self.quorum_closes.load(Ordering::Relaxed),
            late_after_close: self.late_after_close.load(Ordering::Relaxed),
            hist_round_latency: self.hist_round_latency.summary(),
            hist_vote_phase: self.hist_vote_phase.summary(),
            hist_update_phase: self.hist_update_phase.summary(),
            hist_register_stall: self.hist_register_stall.summary(),
            hist_straggler_gap: self.hist_straggler_gap.summary(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    /// Build a `ServerStats` with every counter holding a distinct value
    /// and one distinct sample in every histogram.
    fn distinct_stats() -> ServerStats {
        let stats = ServerStats::default();
        let counters = [
            &stats.packets,
            &stats.decode_errors,
            &stats.duplicates,
            &stats.spilled,
            &stats.spill_dropped,
            &stats.waves,
            &stats.overflow_lanes,
            &stats.register_stalls,
            &stats.reserves_suppressed,
            &stats.idle_releases,
            &stats.downlink_spoofs,
            &stats.non_finite_aux,
            &stats.joins,
            &stats.jobs_created,
            &stats.jobs_rejected,
            &stats.rounds_completed,
            &stats.workers_spawned,
            &stats.idle_wakeups,
            &stats.frames_pooled,
            &stats.pool_misses,
            &stats.steered_frames,
            &stats.quorum_closes,
            &stats.late_after_close,
        ];
        for (i, c) in counters.iter().enumerate() {
            c.store(i as u64 + 1, Ordering::Relaxed);
        }
        let hists = [
            &stats.hist_round_latency,
            &stats.hist_vote_phase,
            &stats.hist_update_phase,
            &stats.hist_register_stall,
            &stats.hist_straggler_gap,
        ];
        for (i, h) in hists.iter().enumerate() {
            h.record(1u64 << (2 * i)); // 1, 4, 16, 64, 256: distinct buckets
        }
        stats
    }

    /// Completeness guard: every `ServerStats` field must survive
    /// `snapshot()` and double under a self-`merge()`. A field added to
    /// the struct but forgotten in either path makes one of these
    /// comparisons fail, so sharded aggregation can't silently drop it.
    #[test]
    fn snapshot_and_merge_carry_every_field() {
        let snap = distinct_stats().snapshot();

        let fields = [
            ("packets", snap.packets),
            ("decode_errors", snap.decode_errors),
            ("duplicates", snap.duplicates),
            ("spilled", snap.spilled),
            ("spill_dropped", snap.spill_dropped),
            ("waves", snap.waves),
            ("overflow_lanes", snap.overflow_lanes),
            ("register_stalls", snap.register_stalls),
            ("reserves_suppressed", snap.reserves_suppressed),
            ("idle_releases", snap.idle_releases),
            ("downlink_spoofs", snap.downlink_spoofs),
            ("non_finite_aux", snap.non_finite_aux),
            ("joins", snap.joins),
            ("jobs_created", snap.jobs_created),
            ("jobs_rejected", snap.jobs_rejected),
            ("rounds_completed", snap.rounds_completed),
            ("workers_spawned", snap.workers_spawned),
            ("idle_wakeups", snap.idle_wakeups),
            ("frames_pooled", snap.frames_pooled),
            ("pool_misses", snap.pool_misses),
            ("steered_frames", snap.steered_frames),
            ("quorum_closes", snap.quorum_closes),
            ("late_after_close", snap.late_after_close),
        ];
        for (i, (name, v)) in fields.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1, "snapshot dropped or shuffled `{name}`");
        }
        let hists = [
            ("hist_round_latency", &snap.hist_round_latency, 1u64),
            ("hist_vote_phase", &snap.hist_vote_phase, 4),
            ("hist_update_phase", &snap.hist_update_phase, 16),
            ("hist_register_stall", &snap.hist_register_stall, 64),
            ("hist_straggler_gap", &snap.hist_straggler_gap, 256),
        ];
        for (name, h, v) in hists {
            assert_eq!(h.count(), 1, "snapshot dropped `{name}`");
            assert_eq!(h.max, v, "snapshot shuffled `{name}`");
        }

        // merge(): identity from zero, then doubling under self-merge.
        let mut from_zero = StatsSnapshot::default();
        from_zero.merge(&snap);
        assert_eq!(from_zero, snap, "merge from zero must be the identity");
        let mut doubled = snap;
        doubled.merge(&snap);
        for (i, (name, _)) in fields.iter().enumerate() {
            let fields2 = [
                doubled.packets,
                doubled.decode_errors,
                doubled.duplicates,
                doubled.spilled,
                doubled.spill_dropped,
                doubled.waves,
                doubled.overflow_lanes,
                doubled.register_stalls,
                doubled.reserves_suppressed,
                doubled.idle_releases,
                doubled.downlink_spoofs,
                doubled.non_finite_aux,
                doubled.joins,
                doubled.jobs_created,
                doubled.jobs_rejected,
                doubled.rounds_completed,
                doubled.workers_spawned,
                doubled.idle_wakeups,
                doubled.frames_pooled,
                doubled.pool_misses,
                doubled.steered_frames,
                doubled.quorum_closes,
                doubled.late_after_close,
            ];
            assert_eq!(fields2[i], 2 * (i as u64 + 1), "merge dropped `{name}`");
        }
        for (name, h, _) in [
            ("hist_round_latency", &doubled.hist_round_latency, 0u64),
            ("hist_vote_phase", &doubled.hist_vote_phase, 0),
            ("hist_update_phase", &doubled.hist_update_phase, 0),
            ("hist_register_stall", &doubled.hist_register_stall, 0),
            ("hist_straggler_gap", &doubled.hist_straggler_gap, 0),
        ] {
            assert_eq!(h.count(), 2, "merge dropped `{name}`");
        }
    }

    /// The metrics JSON line parses with the in-tree parser and carries
    /// every counter key plus the quantile summaries.
    #[test]
    fn metrics_json_line_is_complete_and_parseable() {
        let snap = distinct_stats().snapshot();
        let line = snap.to_json();
        assert!(!line.contains('\n'), "must be a single JSON line");
        let doc = json::parse(&line).unwrap();
        assert_eq!(doc.get("packets").unwrap().as_usize(), Some(1));
        assert_eq!(doc.get("pool_misses").unwrap().as_usize(), Some(20));
        assert_eq!(doc.get("steered_frames").unwrap().as_usize(), Some(21));
        assert_eq!(doc.get("quorum_closes").unwrap().as_usize(), Some(22));
        assert_eq!(doc.get("late_after_close").unwrap().as_usize(), Some(23));
        for key in [
            "round_latency_us",
            "vote_phase_us",
            "update_phase_us",
            "register_stall_us",
            "straggler_gap_us",
        ] {
            let h = doc.get(key).unwrap_or_else(|| panic!("missing `{key}`"));
            assert_eq!(h.get("count").unwrap().as_usize(), Some(1), "{key}");
            for q in ["p50", "p90", "p99", "max"] {
                assert!(h.get(q).is_some(), "{key} missing `{q}`");
            }
        }
        let obj = doc.as_obj().unwrap();
        assert_eq!(obj.len(), 28, "23 counters + 5 histograms");
    }

    fn counter_refs(s: &ServerStats) -> [&AtomicU64; 23] {
        [
            &s.packets,
            &s.decode_errors,
            &s.duplicates,
            &s.spilled,
            &s.spill_dropped,
            &s.waves,
            &s.overflow_lanes,
            &s.register_stalls,
            &s.reserves_suppressed,
            &s.idle_releases,
            &s.downlink_spoofs,
            &s.non_finite_aux,
            &s.joins,
            &s.jobs_created,
            &s.jobs_rejected,
            &s.rounds_completed,
            &s.workers_spawned,
            &s.idle_wakeups,
            &s.frames_pooled,
            &s.pool_misses,
            &s.steered_frames,
            &s.quorum_closes,
            &s.late_after_close,
        ]
    }

    fn hist_refs(s: &ServerStats) -> [&Hist; 5] {
        [
            &s.hist_round_latency,
            &s.hist_vote_phase,
            &s.hist_update_phase,
            &s.hist_register_stall,
            &s.hist_straggler_gap,
        ]
    }

    /// Sharded-aggregation oracle: merging K independently-built
    /// snapshots must equal the snapshot of a single server that saw
    /// the union of every counter bump and histogram sample, and the
    /// fold order must not matter — exactly the guarantee
    /// `serve_sharded` aggregation and `bench-wire --shards` rely on.
    #[test]
    fn k_way_merge_equals_union_of_samples_in_any_order() {
        let mut rng = crate::util::Rng::new(0x57A7_5u64);
        for k in [2usize, 3, 5, 8] {
            let union = ServerStats::default();
            let mut snaps = Vec::with_capacity(k);
            for _ in 0..k {
                let part = ServerStats::default();
                for (pc, uc) in counter_refs(&part).iter().zip(counter_refs(&union)) {
                    let v = rng.below(1 << 20) as u64;
                    pc.store(v, Ordering::Relaxed);
                    uc.fetch_add(v, Ordering::Relaxed);
                }
                for (ph, uh) in hist_refs(&part).iter().zip(hist_refs(&union)) {
                    for _ in 0..rng.below(32) {
                        // Samples spanning the full bucket range.
                        let sample = rng.next_u64() >> rng.below(64);
                        ph.record(sample);
                        uh.record(sample);
                    }
                }
                snaps.push(part.snapshot());
            }
            let expected = union.snapshot();
            let mut forward = StatsSnapshot::default();
            for s in &snaps {
                forward.merge(s);
            }
            assert_eq!(forward, expected, "k={k}: merge fold diverged from the union");
            let mut reverse = StatsSnapshot::default();
            for s in snaps.iter().rev() {
                reverse.merge(s);
            }
            assert_eq!(reverse, expected, "k={k}: merge must be fold-order independent");
        }
    }

    /// Per-core merge regression (ISSUE 9 bugfix satellite): N per-core
    /// summaries that each saw the SAME global-max sample must merge to
    /// exactly N samples at that value with the max itself unchanged —
    /// an exact-max tracker that re-records or double-counts the shared
    /// maximum would inflate the count or the tail quantiles. Pinned
    /// N-way alongside the K-way union oracle above.
    #[test]
    fn n_way_merge_counts_a_shared_global_max_once_per_core() {
        const MAX_US: u64 = 1 << 40; // deep bucket, far from the fillers
        for n in [2usize, 4, 8] {
            let mut merged = StatsSnapshot::default();
            for core in 0..n {
                let part = ServerStats::default();
                // Every core saw the one global maximum exactly once,
                // plus a few core-distinct small fillers.
                part.hist_round_latency.record(MAX_US);
                for _ in 0..core {
                    part.hist_round_latency.record(7);
                }
                merged.merge(&part.snapshot());
            }
            let h = &merged.hist_round_latency;
            assert_eq!(h.max, MAX_US, "n={n}: merged max must be the shared max");
            let fillers = (n * (n - 1) / 2) as u64;
            assert_eq!(
                h.count(),
                n as u64 + fillers,
                "n={n}: shared max must count once per core, never more"
            );
            // The max's bucket holds exactly the n genuine sightings: the
            // p99 of n maxima + tiny fillers still reports the max bucket,
            // and dropping the fillers isolates the tracker itself.
            let mut only_max = StatsSnapshot::default();
            for _ in 0..n {
                let part = ServerStats::default();
                part.hist_round_latency.record(MAX_US);
                only_max.merge(&part.snapshot());
            }
            assert_eq!(only_max.hist_round_latency.count(), n as u64);
            assert_eq!(only_max.hist_round_latency.max, MAX_US);
            assert_eq!(
                only_max.hist_round_latency.quantile(1.0),
                only_max.hist_round_latency.max,
                "n={n}: top quantile must land in the max's bucket"
            );
        }
    }

    /// Fair-share arbitration: with L live tenants no tenant may grow
    /// past cap/L, while a lone tenant still gets the whole cap
    /// (work-conserving) and first-come mode keeps its old semantics.
    #[test]
    fn fair_share_budget_splits_the_cap_across_live_tenants() {
        let fair = HostBudget::new_fair(1200);
        assert_eq!(fair.mode(), BudgetMode::FairShare);
        // Lone tenant: full cap available.
        assert!(fair.try_reserve(1, 1200));
        fair.release(1, 1200);
        assert_eq!(fair.reserved(1), 0);

        // Two live tenants: each is bounded by cap/2 = 600.
        assert!(fair.try_reserve(1, 400));
        assert!(fair.try_reserve(2, 400));
        assert!(!fair.try_reserve(1, 300), "700 > 1200/2 must be refused");
        assert!(fair.try_reserve(1, 200), "topping up to the 600 share is fine");
        // First-come mode would have admitted the same 300-byte top-up.
        let first_come = HostBudget::new(1200);
        assert_eq!(first_come.mode(), BudgetMode::FirstCome);
        assert!(first_come.try_reserve(1, 400));
        assert!(first_come.try_reserve(2, 400));
        assert!(first_come.try_reserve(1, 300));

        // A newcomer shrinks the share: 1200/3 = 400, and the grand
        // total stays bounded by the cap.
        assert!(!fair.try_reserve(3, 401));
        assert!(fair.try_reserve(3, 200));
        assert_eq!(fair.reserved(1), 600);
        assert_eq!(fair.reserved(2), 400);
        assert_eq!(fair.reserved(3), 200);

        // Releases revive the share: tenant 2 leaving returns to L=2.
        fair.release(2, 400);
        assert!(!fair.try_reserve(3, 401), "601 total > the cap/2 = 600 share");
        assert!(fair.try_reserve(3, 400), "back to cap/2 = 600 per tenant");

        // Refused and zero-byte reservations leave no entry behind.
        assert!(!fair.try_reserve(9, usize::MAX));
        assert!(fair.try_reserve(9, 0));
        assert_eq!(fair.reserved(9), 0);
    }

    /// Fair-share never exceeds the deployment-wide cap even when the
    /// live set grew after an earlier tenant grabbed a big share.
    #[test]
    fn fair_share_budget_grand_total_stays_under_the_cap() {
        let fair = HostBudget::new_fair(1000);
        assert!(fair.try_reserve(1, 1000), "lone tenant takes the cap");
        // A newcomer's share is cap/2 = 500, but the cap is exhausted:
        // nothing may be admitted until the incumbent releases.
        assert!(!fair.try_reserve(2, 1));
        fair.release(1, 600);
        assert!(fair.try_reserve(2, 500));
        assert!(!fair.try_reserve(2, 200), "700 total > the cap/2 = 500 share");
        assert_eq!(fair.reserved(1) + fair.reserved(2), 900);
    }
}
