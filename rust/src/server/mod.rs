//! The switch daemon: a UDP aggregation server hosting multiple
//! concurrent FL jobs (multi-tenant), each job running FediAC's two-phase
//! protocol over the [`crate::wire`] format.
//!
//! Architecture (sans-I/O core + pluggable I/O backends, DESIGN.md §6):
//!
//! * [`job`] — the per-job protocol state machine, **sans-I/O**: it owns
//!   no socket and reads no clock. Inputs are decoded frames plus the
//!   caller's `now` ([`Job::handle`]) or timer expiries ([`Job::on_tick`]);
//!   outputs are a [`job::JobOutput`] — datagrams to transmit and the
//!   next deadline to wake at. Per-round vote counters and update
//!   accumulators are backed by the existing
//!   [`crate::switch::RegisterFile`] byte accounting. When a phase's
//!   register demand exceeds the [`crate::configx::PsProfile`] capacity
//!   the block space is processed in *waves*: only a window of blocks is
//!   resident in registers, packets beyond it spill to host memory, and
//!   retired waves copy their partial aggregates out — §III-B's memory
//!   pressure made operational. Duplicate suppression reuses the
//!   [`crate::switch::Scoreboard`] inside the wave aggregators.
//! * [`daemon`] — the front door ([`ServeOptions`], [`serve`],
//!   [`serve_sharded`]) plus the frame-routing/admission rules both
//!   backends share ([`crate::wire::peek_route`], the job cap, the
//!   unknown-job `JoinAck`).
//! * [`threaded`] — the thread-per-job backend: one dispatch thread
//!   routes datagrams to per-job worker threads over channels. Jobs are
//!   concurrent with each other and serialized internally.
//! * [`reactor`] — the single-thread backend: a nonblocking socket, a
//!   readiness poll ([`crate::net::poll`]) and a coarse timer wheel
//!   drive *every* job from one thread — zero per-job threads or
//!   channels, the switch-class resource discipline the paper assumes.
//!
//! Backend choice is wire-invisible: both drive the same [`Job`] state
//! machine, so their GIA/aggregate outputs are bit-identical
//! (`tests/wire_backend.rs` enforces this against the simulator too).

pub mod daemon;
pub mod job;
pub mod reactor;
pub mod threaded;

pub use daemon::{serve, serve_sharded, IoBackend, ServeOptions, ServerHandle};
pub use job::{
    Job, JobLimits, JobOutput, JOIN_BAD_SPEC, JOIN_OK, JOIN_SPEC_MISMATCH, JOIN_UNKNOWN_JOB,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Host-memory accountant: per-tenant (job-id-keyed) byte reservations
/// against one cap. Each daemon normally owns a private accountant, but
/// [`serve_sharded`] hands one `Arc<HostBudget>` to every shard daemon
/// of a deployment so a tenant's [`JobLimits::host_bytes`] bounds its
/// footprint across the *whole* shard set — previously each shard
/// enforced the budget independently, quietly multiplying it by N.
#[derive(Debug)]
pub struct HostBudget {
    cap: usize,
    by_job: Mutex<HashMap<u32, usize>>,
}

impl HostBudget {
    /// Accountant allowing up to `cap` bytes per tenant.
    pub fn new(cap: usize) -> Self {
        HostBudget { cap, by_job: Mutex::new(HashMap::new()) }
    }

    /// The per-tenant byte cap.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Bytes currently reserved by tenant `job`.
    pub fn reserved(&self, job: u32) -> usize {
        self.by_job.lock().unwrap().get(&job).copied().unwrap_or(0)
    }

    /// Reserve `bytes` for tenant `job`; false when the tenant's total
    /// would exceed the cap (nothing is charged then). A refused or
    /// zero-byte reservation leaves no map entry behind — unauthenticated
    /// Join sprays with over-budget specs must not grow this table.
    pub fn try_reserve(&self, job: u32, bytes: usize) -> bool {
        let mut m = self.by_job.lock().unwrap();
        let cur = m.get(&job).copied().unwrap_or(0);
        match cur.checked_add(bytes) {
            Some(total) if total <= self.cap => {
                if total > 0 {
                    m.insert(job, total);
                }
                true
            }
            _ => false,
        }
    }

    /// Return `bytes` of tenant `job`'s reservation.
    pub fn release(&self, job: u32, bytes: usize) {
        let mut m = self.by_job.lock().unwrap();
        if let Some(cur) = m.get_mut(&job) {
            *cur = cur.saturating_sub(bytes);
            if *cur == 0 {
                m.remove(&job);
            }
        }
    }
}

/// Cross-thread daemon counters (lock-free; workers update directly).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Datagrams received by the dispatch loop (valid or not).
    pub packets: AtomicU64,
    /// Frames dropped for malformed bytes or impossible geometry
    /// (bad route peek, failed decode, out-of-range block/elems/client).
    pub decode_errors: AtomicU64,
    /// Frames dropped as already-seen contributions (scoreboard hits,
    /// stale-block replays, re-buffered spill, post-completion data).
    pub duplicates: AtomicU64,
    /// Data blocks buffered to host memory because they landed beyond
    /// the resident register wave.
    pub spilled: AtomicU64,
    /// Spill entries dropped at the per-round cap (repaired by client
    /// retransmission once the wave advances).
    pub spill_dropped: AtomicU64,
    /// Register waves advanced past the first (each bump = one wave
    /// retired and the window moved, §III-B memory pressure).
    pub waves: AtomicU64,
    /// Aggregate lanes that saturated i32 during accumulation.
    pub overflow_lanes: AtomicU64,
    /// Wave allocations refused for lack of register memory (the round
    /// keeps spilling until another wave releases).
    pub register_stalls: AtomicU64,
    /// Full GIA/aggregate re-serves refused by the per-source budget
    /// (UDP reflection damping).
    pub reserves_suppressed: AtomicU64,
    /// Register aggregators reclaimed from rounds with no recent traffic.
    pub idle_releases: AtomicU64,
    /// Server-bound frames of downlink-only kinds (Gia / Aggregate /
    /// JoinAck / NotReady) dropped without a reply (anti-reflection).
    pub downlink_spoofs: AtomicU64,
    /// Vote frames rejected because their local-max aux was NaN/Inf
    /// (would poison the job-wide scale factor).
    pub non_finite_aux: AtomicU64,
    /// Join frames accepted (including idempotent re-joins).
    pub joins: AtomicU64,
    /// Jobs configured by a first valid Join.
    pub jobs_created: AtomicU64,
    /// Datagrams dropped because the per-daemon job cap was reached.
    pub jobs_rejected: AtomicU64,
    /// Rounds whose phase-2 aggregate completed (or closed empty).
    pub rounds_completed: AtomicU64,
    /// Worker threads spawned by the threaded backend. The reactor
    /// backend never bumps this — one thread serves every job
    /// (`tests/wire_backend.rs` asserts zero per-job spawns through it).
    pub workers_spawned: AtomicU64,
    /// Backend wakeups driven by a [`Job`] timer deadline rather than by
    /// traffic (idle register reclamation). The busy-wake regression
    /// guard: an idle daemon must not accumulate these, because backends
    /// sleep until the job's own deadline instead of polling on a fixed
    /// tick.
    pub idle_wakeups: AtomicU64,
    /// Outgoing frame buffers served from the per-job
    /// [`crate::wire::FrameScratch`] pool (recycled, no allocation).
    pub frames_pooled: AtomicU64,
    /// Outgoing frame buffers freshly allocated because the pool was
    /// empty. Grows during warm-up only: steady-state rounds must hold
    /// this flat (`fediac bench-codec` / `bench-wire` assert it).
    pub pool_misses: AtomicU64,
}

/// Point-in-time copy of [`ServerStats`] for reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// See [`ServerStats::packets`].
    pub packets: u64,
    /// See [`ServerStats::decode_errors`].
    pub decode_errors: u64,
    /// See [`ServerStats::duplicates`].
    pub duplicates: u64,
    /// See [`ServerStats::spilled`].
    pub spilled: u64,
    /// See [`ServerStats::spill_dropped`].
    pub spill_dropped: u64,
    /// See [`ServerStats::waves`].
    pub waves: u64,
    /// See [`ServerStats::overflow_lanes`].
    pub overflow_lanes: u64,
    /// See [`ServerStats::register_stalls`].
    pub register_stalls: u64,
    /// See [`ServerStats::reserves_suppressed`].
    pub reserves_suppressed: u64,
    /// See [`ServerStats::idle_releases`].
    pub idle_releases: u64,
    /// See [`ServerStats::downlink_spoofs`].
    pub downlink_spoofs: u64,
    /// See [`ServerStats::non_finite_aux`].
    pub non_finite_aux: u64,
    /// See [`ServerStats::joins`].
    pub joins: u64,
    /// See [`ServerStats::jobs_created`].
    pub jobs_created: u64,
    /// See [`ServerStats::jobs_rejected`].
    pub jobs_rejected: u64,
    /// See [`ServerStats::rounds_completed`].
    pub rounds_completed: u64,
    /// See [`ServerStats::workers_spawned`].
    pub workers_spawned: u64,
    /// See [`ServerStats::idle_wakeups`].
    pub idle_wakeups: u64,
    /// See [`ServerStats::frames_pooled`].
    pub frames_pooled: u64,
    /// See [`ServerStats::pool_misses`].
    pub pool_misses: u64,
}

impl StatsSnapshot {
    /// Fold another daemon's counters in — the single place that knows
    /// every field, so multi-shard aggregation (the shard-aware wire
    /// bench) cannot silently drop a counter added later.
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.packets += other.packets;
        self.decode_errors += other.decode_errors;
        self.duplicates += other.duplicates;
        self.spilled += other.spilled;
        self.spill_dropped += other.spill_dropped;
        self.waves += other.waves;
        self.overflow_lanes += other.overflow_lanes;
        self.register_stalls += other.register_stalls;
        self.reserves_suppressed += other.reserves_suppressed;
        self.idle_releases += other.idle_releases;
        self.downlink_spoofs += other.downlink_spoofs;
        self.non_finite_aux += other.non_finite_aux;
        self.joins += other.joins;
        self.jobs_created += other.jobs_created;
        self.jobs_rejected += other.jobs_rejected;
        self.rounds_completed += other.rounds_completed;
        self.workers_spawned += other.workers_spawned;
        self.idle_wakeups += other.idle_wakeups;
        self.frames_pooled += other.frames_pooled;
        self.pool_misses += other.pool_misses;
    }
}

impl ServerStats {
    /// Increment one counter (relaxed; counters are advisory).
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n` to one counter (relaxed).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Copy every counter at one point in time.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            spill_dropped: self.spill_dropped.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            overflow_lanes: self.overflow_lanes.load(Ordering::Relaxed),
            register_stalls: self.register_stalls.load(Ordering::Relaxed),
            reserves_suppressed: self.reserves_suppressed.load(Ordering::Relaxed),
            idle_releases: self.idle_releases.load(Ordering::Relaxed),
            downlink_spoofs: self.downlink_spoofs.load(Ordering::Relaxed),
            non_finite_aux: self.non_finite_aux.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            jobs_created: self.jobs_created.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            rounds_completed: self.rounds_completed.load(Ordering::Relaxed),
            workers_spawned: self.workers_spawned.load(Ordering::Relaxed),
            idle_wakeups: self.idle_wakeups.load(Ordering::Relaxed),
            frames_pooled: self.frames_pooled.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
        }
    }
}
