//! The switch daemon: a threaded UDP aggregation server hosting multiple
//! concurrent FL jobs (multi-tenant), each job running FediAC's two-phase
//! protocol over the [`crate::wire`] format.
//!
//! Architecture:
//!
//! * [`daemon`] — socket front-end: one dispatch thread routes datagrams
//!   by job id ([`crate::wire::peek_route`]) to per-job worker threads,
//!   so independent jobs aggregate concurrently while each job's state
//!   stays single-threaded (the same invariant a real switch pipeline
//!   gives per-register-block).
//! * [`job`] — the per-job protocol state machine: per-round vote
//!   counters and update accumulators backed by the existing
//!   [`crate::switch::RegisterFile`] byte accounting. When a phase's
//!   register demand exceeds the [`crate::configx::PsProfile`] capacity
//!   the block space is processed in *waves*: only a window of blocks is
//!   resident in registers, packets beyond it spill to host memory, and
//!   retired waves copy their partial aggregates out — §III-B's memory
//!   pressure made operational. Duplicate suppression reuses the
//!   [`crate::switch::Scoreboard`] inside the wave aggregators.

pub mod daemon;
pub mod job;

pub use daemon::{serve, ServeOptions, ServerHandle};
pub use job::{Job, JobLimits, JOIN_BAD_SPEC, JOIN_OK, JOIN_SPEC_MISMATCH, JOIN_UNKNOWN_JOB};

use std::sync::atomic::{AtomicU64, Ordering};

/// Cross-thread daemon counters (lock-free; workers update directly).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub packets: AtomicU64,
    pub decode_errors: AtomicU64,
    pub duplicates: AtomicU64,
    pub spilled: AtomicU64,
    /// Spill entries dropped at the per-round cap (repaired by client
    /// retransmission once the wave advances).
    pub spill_dropped: AtomicU64,
    pub waves: AtomicU64,
    pub overflow_lanes: AtomicU64,
    pub register_stalls: AtomicU64,
    /// Full GIA/aggregate re-serves refused by the per-source budget
    /// (UDP reflection damping).
    pub reserves_suppressed: AtomicU64,
    /// Register aggregators reclaimed from rounds with no recent traffic.
    pub idle_releases: AtomicU64,
    /// Server-bound frames of downlink-only kinds (Gia / Aggregate /
    /// JoinAck / NotReady) dropped without a reply (anti-reflection).
    pub downlink_spoofs: AtomicU64,
    /// Vote frames rejected because their local-max aux was NaN/Inf
    /// (would poison the job-wide scale factor).
    pub non_finite_aux: AtomicU64,
    pub joins: AtomicU64,
    pub jobs_created: AtomicU64,
    /// Datagrams dropped because the per-daemon job cap was reached.
    pub jobs_rejected: AtomicU64,
    pub rounds_completed: AtomicU64,
}

/// Point-in-time copy of [`ServerStats`] for reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub packets: u64,
    pub decode_errors: u64,
    pub duplicates: u64,
    pub spilled: u64,
    pub spill_dropped: u64,
    pub waves: u64,
    pub overflow_lanes: u64,
    pub register_stalls: u64,
    pub reserves_suppressed: u64,
    pub idle_releases: u64,
    pub downlink_spoofs: u64,
    pub non_finite_aux: u64,
    pub joins: u64,
    pub jobs_created: u64,
    pub jobs_rejected: u64,
    pub rounds_completed: u64,
}

impl ServerStats {
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            spill_dropped: self.spill_dropped.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            overflow_lanes: self.overflow_lanes.load(Ordering::Relaxed),
            register_stalls: self.register_stalls.load(Ordering::Relaxed),
            reserves_suppressed: self.reserves_suppressed.load(Ordering::Relaxed),
            idle_releases: self.idle_releases.load(Ordering::Relaxed),
            downlink_spoofs: self.downlink_spoofs.load(Ordering::Relaxed),
            non_finite_aux: self.non_finite_aux.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            jobs_created: self.jobs_created.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            rounds_completed: self.rounds_completed.load(Ordering::Relaxed),
        }
    }
}
