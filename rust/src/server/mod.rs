//! The switch daemon: a threaded UDP aggregation server hosting multiple
//! concurrent FL jobs (multi-tenant), each job running FediAC's two-phase
//! protocol over the [`crate::wire`] format.
//!
//! Architecture:
//!
//! * [`daemon`] — socket front-end: one dispatch thread routes datagrams
//!   by job id ([`crate::wire::peek_route`]) to per-job worker threads,
//!   so independent jobs aggregate concurrently while each job's state
//!   stays single-threaded (the same invariant a real switch pipeline
//!   gives per-register-block).
//! * [`job`] — the per-job protocol state machine: per-round vote
//!   counters and update accumulators backed by the existing
//!   [`crate::switch::RegisterFile`] byte accounting. When a phase's
//!   register demand exceeds the [`crate::configx::PsProfile`] capacity
//!   the block space is processed in *waves*: only a window of blocks is
//!   resident in registers, packets beyond it spill to host memory, and
//!   retired waves copy their partial aggregates out — §III-B's memory
//!   pressure made operational. Duplicate suppression reuses the
//!   [`crate::switch::Scoreboard`] inside the wave aggregators.

pub mod daemon;
pub mod job;

pub use daemon::{serve, serve_sharded, ServeOptions, ServerHandle};
pub use job::{Job, JobLimits, JOIN_BAD_SPEC, JOIN_OK, JOIN_SPEC_MISMATCH, JOIN_UNKNOWN_JOB};

use std::sync::atomic::{AtomicU64, Ordering};

/// Cross-thread daemon counters (lock-free; workers update directly).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Datagrams received by the dispatch loop (valid or not).
    pub packets: AtomicU64,
    /// Frames dropped for malformed bytes or impossible geometry
    /// (bad route peek, failed decode, out-of-range block/elems/client).
    pub decode_errors: AtomicU64,
    /// Frames dropped as already-seen contributions (scoreboard hits,
    /// stale-block replays, re-buffered spill, post-completion data).
    pub duplicates: AtomicU64,
    /// Data blocks buffered to host memory because they landed beyond
    /// the resident register wave.
    pub spilled: AtomicU64,
    /// Spill entries dropped at the per-round cap (repaired by client
    /// retransmission once the wave advances).
    pub spill_dropped: AtomicU64,
    /// Register waves advanced past the first (each bump = one wave
    /// retired and the window moved, §III-B memory pressure).
    pub waves: AtomicU64,
    /// Aggregate lanes that saturated i32 during accumulation.
    pub overflow_lanes: AtomicU64,
    /// Wave allocations refused for lack of register memory (the round
    /// keeps spilling until another wave releases).
    pub register_stalls: AtomicU64,
    /// Full GIA/aggregate re-serves refused by the per-source budget
    /// (UDP reflection damping).
    pub reserves_suppressed: AtomicU64,
    /// Register aggregators reclaimed from rounds with no recent traffic.
    pub idle_releases: AtomicU64,
    /// Server-bound frames of downlink-only kinds (Gia / Aggregate /
    /// JoinAck / NotReady) dropped without a reply (anti-reflection).
    pub downlink_spoofs: AtomicU64,
    /// Vote frames rejected because their local-max aux was NaN/Inf
    /// (would poison the job-wide scale factor).
    pub non_finite_aux: AtomicU64,
    /// Join frames accepted (including idempotent re-joins).
    pub joins: AtomicU64,
    /// Jobs configured by a first valid Join.
    pub jobs_created: AtomicU64,
    /// Datagrams dropped because the per-daemon job cap was reached.
    pub jobs_rejected: AtomicU64,
    /// Rounds whose phase-2 aggregate completed (or closed empty).
    pub rounds_completed: AtomicU64,
}

/// Point-in-time copy of [`ServerStats`] for reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// See [`ServerStats::packets`].
    pub packets: u64,
    /// See [`ServerStats::decode_errors`].
    pub decode_errors: u64,
    /// See [`ServerStats::duplicates`].
    pub duplicates: u64,
    /// See [`ServerStats::spilled`].
    pub spilled: u64,
    /// See [`ServerStats::spill_dropped`].
    pub spill_dropped: u64,
    /// See [`ServerStats::waves`].
    pub waves: u64,
    /// See [`ServerStats::overflow_lanes`].
    pub overflow_lanes: u64,
    /// See [`ServerStats::register_stalls`].
    pub register_stalls: u64,
    /// See [`ServerStats::reserves_suppressed`].
    pub reserves_suppressed: u64,
    /// See [`ServerStats::idle_releases`].
    pub idle_releases: u64,
    /// See [`ServerStats::downlink_spoofs`].
    pub downlink_spoofs: u64,
    /// See [`ServerStats::non_finite_aux`].
    pub non_finite_aux: u64,
    /// See [`ServerStats::joins`].
    pub joins: u64,
    /// See [`ServerStats::jobs_created`].
    pub jobs_created: u64,
    /// See [`ServerStats::jobs_rejected`].
    pub jobs_rejected: u64,
    /// See [`ServerStats::rounds_completed`].
    pub rounds_completed: u64,
}

impl ServerStats {
    /// Increment one counter (relaxed; counters are advisory).
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n` to one counter (relaxed).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Copy every counter at one point in time.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            packets: self.packets.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            spill_dropped: self.spill_dropped.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            overflow_lanes: self.overflow_lanes.load(Ordering::Relaxed),
            register_stalls: self.register_stalls.load(Ordering::Relaxed),
            reserves_suppressed: self.reserves_suppressed.load(Ordering::Relaxed),
            idle_releases: self.idle_releases.load(Ordering::Relaxed),
            downlink_spoofs: self.downlink_spoofs.load(Ordering::Relaxed),
            non_finite_aux: self.non_finite_aux.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            jobs_created: self.jobs_created.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            rounds_completed: self.rounds_completed.load(Ordering::Relaxed),
        }
    }
}
