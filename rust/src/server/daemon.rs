//! Threaded UDP front-end for the aggregation server.
//!
//! One dispatch thread owns the socket's receive side and routes datagrams
//! by job id (a cheap [`peek_route`] — no checksum work on the hot thread)
//! to per-job worker threads over mpsc channels. Each worker owns its
//! [`Job`] state exclusively (no locks on the aggregation path) and sends
//! replies through a cloned socket handle. Jobs are therefore concurrent
//! with each other and serialized internally — the same discipline a
//! switch pipeline imposes per register block.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::configx::PsProfile;
use crate::net::chaos::{ChaosDirection, ChaosLane};
use crate::server::job::{Job, JobLimits, JOIN_UNKNOWN_JOB};
use crate::server::{ServerStats, StatsSnapshot};
use crate::wire::{decode_frame, encode_frame, peek_route, Header, WireKind};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. "0.0.0.0:7177" or "127.0.0.1:0" for tests.
    pub bind: String,
    /// Switch profile — its `memory_bytes` drives per-job wave behaviour.
    pub profile: PsProfile,
    /// Per-job abuse limits: host-memory budget enforced at `Join`, spill
    /// caps, idle register reclamation, and re-serve rate limiting.
    pub limits: JobLimits,
    /// Downlink chaos injection point: run every worker-sent datagram
    /// (GIA/aggregate multicasts, acks, re-serves) through a seeded
    /// [`ChaosLane`] — loss/dup/reorder/corruption on the server→client
    /// path without an external proxy. Lanes are per worker, seeded from
    /// `chaos_seed ^ job_id`.
    pub downlink_chaos: Option<ChaosDirection>,
    /// Root seed for `downlink_chaos` lanes.
    pub chaos_seed: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            bind: "127.0.0.1:0".to_string(),
            profile: PsProfile::high(),
            limits: JobLimits::default(),
            downlink_chaos: None,
            chaos_seed: 0,
        }
    }
}

/// Running daemon handle: address, live stats, shutdown.
pub struct ServerHandle {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    dispatch: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound socket address (useful with an ephemeral bind port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time copy of the daemon's counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Stop the dispatch loop and join every worker.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
    }
}

/// Launch `n_shards` collaborating daemons in one process — shard `s` of
/// the deployment PROTOCOL.md §8 describes listens on `base.bind`'s port
/// plus `s` (an ephemeral port 0 in `base.bind` gives every shard its own
/// ephemeral port instead). Each shard is a full, independent
/// [`serve`] instance with its own socket, workers and stats; clients
/// address shard `s` with a [`crate::wire::JobSpec`] whose `shard` field
/// names slice `s`. Returns one handle per shard, index = shard id.
pub fn serve_sharded(base: &ServeOptions, n_shards: u8) -> io::Result<Vec<ServerHandle>> {
    if n_shards == 0 || n_shards > crate::wire::MAX_SHARDS {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "n_shards must be in [1, 16]",
        ));
    }
    let (host, port) = base
        .bind
        .rsplit_once(':')
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "bind must be host:port"))?;
    let port: u16 = port
        .parse()
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "bind port must be a u16"))?;
    let mut handles = Vec::with_capacity(n_shards as usize);
    for s in 0..n_shards {
        let bind = if port == 0 {
            format!("{host}:0")
        } else {
            let p = port.checked_add(s as u16).ok_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidInput, "shard port range overflows u16")
            })?;
            format!("{host}:{p}")
        };
        let opts = ServeOptions {
            bind,
            // Decorrelate per-shard downlink chaos streams the same way
            // the proxy decorrelates per-flow lanes.
            chaos_seed: base.chaos_seed ^ ((s as u64) << 32),
            ..base.clone()
        };
        handles.push(serve(&opts)?);
    }
    Ok(handles)
}

/// Bind a socket and start the dispatch + worker threads.
pub fn serve(opts: &ServeOptions) -> io::Result<ServerHandle> {
    let socket = UdpSocket::bind(&opts.bind)?;
    socket.set_read_timeout(Some(Duration::from_millis(25)))?;
    let addr = socket.local_addr()?;
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));

    let dispatch = {
        let stats = Arc::clone(&stats);
        let stop = Arc::clone(&stop);
        let profile = opts.profile.clone();
        let limits = opts.limits;
        let chaos = opts.downlink_chaos;
        let chaos_seed = opts.chaos_seed;
        thread::Builder::new().name("fediac-dispatch".into()).spawn(move || {
            dispatch_loop(socket, profile, limits, chaos, chaos_seed, stats, stop);
        })?
    };

    Ok(ServerHandle { addr, stats, stop, dispatch: Some(dispatch) })
}

type WorkerTx = Sender<(Vec<u8>, SocketAddr)>;

/// One spawned job worker: its input channel, its thread handle, and
/// whether its `Job` has been configured by a valid `Join` (unconfigured
/// workers are the eviction candidates under cap pressure).
struct WorkerSlot {
    tx: WorkerTx,
    handle: JoinHandle<()>,
    configured: Arc<AtomicBool>,
}

/// Upper bound on concurrently hosted jobs (= worker threads). Workers
/// are born only on `Join` frames, and when the cap is hit a worker whose
/// job never completed a valid `Join` (a forged or abandoned id) is
/// evicted first, so spraying job ids can neither spawn unbounded OS
/// threads nor permanently lock new tenants out.
const MAX_JOBS: usize = 256;

fn dispatch_loop(
    socket: UdpSocket,
    profile: PsProfile,
    limits: JobLimits,
    chaos: Option<ChaosDirection>,
    chaos_seed: u64,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
) {
    let mut workers: HashMap<u32, WorkerSlot> = HashMap::new();
    let mut buf = vec![0u8; 65536];
    while !stop.load(Ordering::SeqCst) {
        let (n, from) = match socket.recv_from(&mut buf) {
            Ok(ok) => ok,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        };
        ServerStats::bump(&stats.packets);
        let Some((job_id, kind)) = peek_route(&buf[..n]) else {
            ServerStats::bump(&stats.decode_errors);
            continue;
        };
        if !workers.contains_key(&job_id) {
            // Workers are born only on Join. Genuine uplink data frames
            // for unknown jobs get the protocol's JoinAck/UNKNOWN
            // straight from this thread (the client driver re-joins on
            // seeing it), so a sprayed job id cannot pin an OS thread.
            // Server-bound spoofs of downlink kinds earn no reply at all
            // — answering them would reflect traffic at forged sources.
            if kind != WireKind::Join {
                if matches!(kind, WireKind::Vote | WireKind::Update | WireKind::Poll) {
                    let h =
                        Header::control(WireKind::JoinAck, job_id, u16::MAX, 0, JOIN_UNKNOWN_JOB);
                    let _ = socket.send_to(&encode_frame(&h, &[]), from);
                } else {
                    ServerStats::bump(&stats.downlink_spoofs);
                }
                continue;
            }
            if workers.len() >= MAX_JOBS && !evict_unconfigured(&mut workers) {
                ServerStats::bump(&stats.jobs_rejected);
                continue;
            }
        }
        let worker = workers.entry(job_id).or_insert_with(|| {
            spawn_worker(job_id, &socket, profile.clone(), limits, chaos, chaos_seed, Arc::clone(&stats))
        });
        if worker.tx.send((buf[..n].to_vec(), from)).is_err() {
            // Worker died (should not happen); drop the datagram — the
            // client's retransmission will respawn it.
            workers.remove(&job_id);
        }
    }
    for (_, slot) in workers {
        drop(slot.tx);
        let _ = slot.handle.join();
    }
}

/// Drop one worker whose job was never configured by a valid `Join`.
/// Returns false when every resident job is real (the cap then holds).
fn evict_unconfigured(workers: &mut HashMap<u32, WorkerSlot>) -> bool {
    let victim = workers
        .iter()
        .find(|(_, slot)| !slot.configured.load(Ordering::SeqCst))
        .map(|(&id, _)| id);
    let Some(id) = victim else {
        return false;
    };
    if let Some(slot) = workers.remove(&id) {
        drop(slot.tx);
        let _ = slot.handle.join();
    }
    true
}

/// How often a chaos-enabled worker wakes to flush overdue held-back
/// downlink datagrams.
const CHAOS_TICK: Duration = Duration::from_millis(10);

fn spawn_worker(
    job_id: u32,
    socket: &UdpSocket,
    profile: PsProfile,
    limits: JobLimits,
    chaos: Option<ChaosDirection>,
    chaos_seed: u64,
    stats: Arc<ServerStats>,
) -> WorkerSlot {
    let (tx, rx) = mpsc::channel::<(Vec<u8>, SocketAddr)>();
    let out = socket.try_clone().expect("cloning UDP socket for worker");
    let configured = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&configured);
    let handle = thread::Builder::new()
        .name(format!("fediac-job-{job_id}"))
        .spawn(move || {
            let mut job = Job::with_limits(job_id, profile, limits, Arc::clone(&stats));
            // Downlink chaos lane (None = send straight through). Held
            // copies carry their destination as lane metadata.
            let mut lane: Option<ChaosLane<SocketAddr>> =
                chaos.map(|cfg| ChaosLane::new(cfg, chaos_seed ^ job_id as u64));
            loop {
                // With a lane attached the worker must wake on idle to
                // release overdue reordered datagrams; without one it
                // blocks cheaply on the channel.
                let msg = if lane.is_some() {
                    match rx.recv_timeout(CHAOS_TICK) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                } else {
                    match rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    }
                };
                if let Some((datagram, from)) = msg {
                    match decode_frame(&datagram) {
                        Ok(frame) => {
                            for (dest, bytes) in job.handle(&frame, from) {
                                match lane.as_mut() {
                                    Some(l) => {
                                        for (pkt, to) in l.process(&bytes, dest, Instant::now()) {
                                            let _ = out.send_to(&pkt, to);
                                        }
                                    }
                                    None => {
                                        let _ = out.send_to(&bytes, dest);
                                    }
                                }
                            }
                            if !flag.load(Ordering::SeqCst) && job.is_configured() {
                                flag.store(true, Ordering::SeqCst);
                            }
                        }
                        Err(_) => ServerStats::bump(&stats.decode_errors),
                    }
                }
                if let Some(l) = lane.as_mut() {
                    for (pkt, to) in l.flush_due(Instant::now()) {
                        let _ = out.send_to(&pkt, to);
                    }
                }
            }
        })
        .expect("spawning job worker");
    WorkerSlot { tx, handle, configured }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{encode_frame, Header, JobSpec, ShardPlan, WireKind};

    #[test]
    fn daemon_starts_acks_join_and_shuts_down() {
        let handle = serve(&ServeOptions::default()).unwrap();
        let addr = handle.local_addr();

        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let spec = JobSpec {
            d: 64,
            n_clients: 1,
            threshold_a: 1,
            payload_budget: 8,
            shard: ShardPlan::single(),
        };
        let join = encode_frame(&Header::control(WireKind::Join, 5, 0, 0, 0), &spec.encode());
        client.send_to(&join, addr).unwrap();

        let mut buf = [0u8; 2048];
        let (n, _) = client.recv_from(&mut buf).unwrap();
        let frame = decode_frame(&buf[..n]).unwrap();
        assert_eq!(frame.header.kind, WireKind::JoinAck);
        assert_eq!(frame.header.aux, crate::server::JOIN_OK);

        // Garbage is counted, not fatal.
        client.send_to(b"not a frame", addr).unwrap();
        // A second job spins up its own worker.
        let join2 = encode_frame(&Header::control(WireKind::Join, 6, 0, 0, 0), &spec.encode());
        client.send_to(&join2, addr).unwrap();
        let (n, _) = client.recv_from(&mut buf).unwrap();
        assert_eq!(decode_frame(&buf[..n]).unwrap().header.job, 6);

        // A data frame for a job nobody joined is answered straight from
        // the dispatch thread — no worker slot is spent on it.
        let stray = encode_frame(
            &Header {
                kind: WireKind::Vote,
                client: 0,
                job: 999,
                round: 0,
                block: 0,
                n_blocks: 1,
                elems: 8,
                aux: 0,
            },
            &[0u8; 1],
        );
        client.send_to(&stray, addr).unwrap();
        let (n, _) = client.recv_from(&mut buf).unwrap();
        let f = decode_frame(&buf[..n]).unwrap();
        assert_eq!(f.header.kind, WireKind::JoinAck);
        assert_eq!(f.header.job, 999);
        assert_eq!(f.header.aux, crate::server::JOIN_UNKNOWN_JOB);

        // A server-bound spoof of a *downlink* kind gets no reply at all
        // (a JoinAck echo here would be reflection fodder).
        let spoof = encode_frame(
            &Header {
                kind: WireKind::Gia,
                client: u16::MAX,
                job: 31337,
                round: 0,
                block: 0,
                n_blocks: 1,
                elems: 0,
                aux: 0,
            },
            &[],
        );
        client.send_to(&spoof, addr).unwrap();
        client.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        let mut tmp = [0u8; 64];
        assert!(client.recv_from(&mut tmp).is_err(), "spoofed downlink frame was answered");

        let stats = handle.stats();
        assert!(stats.packets >= 3);
        assert_eq!(stats.jobs_created, 2);
        assert!(stats.decode_errors >= 1);
        assert!(stats.downlink_spoofs >= 1);
        handle.shutdown();
    }

    #[test]
    fn sharded_daemons_bind_and_ack_shard_specs() {
        let handles = serve_sharded(&ServeOptions::default(), 2).unwrap();
        assert_eq!(handles.len(), 2);
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        for (s, h) in handles.iter().enumerate() {
            let spec = JobSpec {
                d: 64,
                n_clients: 1,
                threshold_a: 1,
                payload_budget: 8,
                shard: ShardPlan { n_shards: 2, shard_id: s as u8 },
            };
            let join =
                encode_frame(&Header::control(WireKind::Join, 11, 0, 0, 0), &spec.encode());
            client.send_to(&join, h.local_addr()).unwrap();
            let mut buf = [0u8; 256];
            let (n, _) = client.recv_from(&mut buf).unwrap();
            let f = decode_frame(&buf[..n]).unwrap();
            assert_eq!(f.header.kind, WireKind::JoinAck);
            assert_eq!(f.header.aux, crate::server::JOIN_OK, "shard {s} refused its spec");
        }
        assert_ne!(handles[0].local_addr(), handles[1].local_addr());
        for h in handles {
            h.shutdown();
        }
    }

    #[test]
    fn sharded_serve_rejects_bad_shard_counts() {
        assert!(serve_sharded(&ServeOptions::default(), 0).is_err());
        assert!(serve_sharded(&ServeOptions::default(), 17).is_err());
    }

    #[test]
    fn downlink_chaos_lane_reaches_worker_sends() {
        // Full downlink drop: the worker's JoinAck never escapes.
        let handle = serve(&ServeOptions {
            downlink_chaos: Some(ChaosDirection::lossy(1.0, 0.0, 0.0)),
            chaos_seed: 5,
            ..ServeOptions::default()
        })
        .unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
        let spec = JobSpec {
            d: 64,
            n_clients: 1,
            threshold_a: 1,
            payload_budget: 8,
            shard: ShardPlan::single(),
        };
        let join = encode_frame(&Header::control(WireKind::Join, 8, 0, 0, 0), &spec.encode());
        client.send_to(&join, handle.local_addr()).unwrap();
        let mut buf = [0u8; 256];
        assert!(client.recv_from(&mut buf).is_err(), "dropped JoinAck arrived");
        assert_eq!(handle.stats().joins, 1, "join itself must still register");
        handle.shutdown();
    }
}
