//! Front door of the aggregation server: configuration
//! ([`ServeOptions`], [`IoBackend`]), the running-daemon handle, shard
//! fan-out ([`serve_sharded`]) and the routing/admission rules shared by
//! both I/O backends.
//!
//! [`serve`] binds the socket and hands it to the selected backend:
//!
//! * [`IoBackend::Threaded`] → [`crate::server::threaded`]: one dispatch
//!   thread plus one worker thread per hosted job;
//! * [`IoBackend::Reactor`] → [`crate::server::reactor`]: one thread,
//!   zero per-job threads or channels — a nonblocking socket, readiness
//!   polling and a coarse timer wheel multiplex every job.
//! * [`IoBackend::Fleet`] → [`crate::server::fleet`]: N reactor cores
//!   sharing one port through an `SO_REUSEPORT` socket group, jobs
//!   partitioned across cores by id hash, misdirected datagrams
//!   forwarded core-to-core, and one fair-share [`HostBudget`] Arc
//!   shared by every core.
//!
//! All backends drive the same sans-I/O [`crate::server::Job`] state
//! machine, so the choice is invisible on the wire (PROTOCOL.md) and
//! bit-exact (`tests/wire_backend.rs`).

use std::io;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::configx::PsProfile;
use crate::net::chaos::{ChaosDirection, ChaosLane};
use crate::server::job::{JobLimits, Outgoing, JOIN_UNKNOWN_JOB};
use crate::server::{fleet, reactor, threaded, HostBudget, ServerStats, StatsSnapshot};
use crate::telemetry::{FlightRecorder, TraceNote};
use crate::wire::{encode_frame, Header, WireKind};

/// Which event engine hosts the jobs. Every engine runs the identical
/// sans-I/O [`crate::server::Job`] core; they differ only in how
/// datagrams and timer deadlines reach it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoBackend {
    /// One dispatch thread + one worker thread (and channel) per job.
    /// Jobs aggregate concurrently on multi-core hosts.
    #[default]
    Threaded,
    /// One thread for everything: nonblocking socket, readiness poll
    /// ([`crate::net::poll`]) and a coarse timer wheel. The switch-class
    /// discipline — thousands of clients on a fixed compute budget.
    Reactor,
    /// N reactor cores on one port (`SO_REUSEPORT` socket group), jobs
    /// partitioned across cores by id hash with core-to-core forwarding
    /// for flow-misdirected datagrams — the whole machine serves, one
    /// reactor discipline per core ([`ServeOptions::cores`]).
    Fleet,
}

impl IoBackend {
    /// Parse a backend name (`"threaded"` / `"reactor"` / `"fleet"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "threaded" => Some(IoBackend::Threaded),
            "reactor" => Some(IoBackend::Reactor),
            "fleet" => Some(IoBackend::Fleet),
            _ => None,
        }
    }

    /// The backend's CLI name.
    pub fn name(self) -> &'static str {
        match self {
            IoBackend::Threaded => "threaded",
            IoBackend::Reactor => "reactor",
            IoBackend::Fleet => "fleet",
        }
    }

    /// Backend selected by the `FEDIAC_IO` environment variable, falling
    /// back to [`IoBackend::Threaded`] when unset. This is how CI runs
    /// the whole wire test suite under the reactor without touching the
    /// tests ([`ServeOptions::default`] consults it). An unparsable
    /// value panics rather than silently running the wrong backend.
    pub fn from_env() -> Self {
        match std::env::var("FEDIAC_IO") {
            Ok(v) => IoBackend::parse(&v).unwrap_or_else(|| {
                panic!("FEDIAC_IO='{v}' is not 'threaded', 'reactor' or 'fleet'")
            }),
            Err(_) => IoBackend::default(),
        }
    }
}

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. "0.0.0.0:7177" or "127.0.0.1:0" for tests.
    pub bind: String,
    /// Switch profile — its `memory_bytes` drives per-job wave behaviour.
    pub profile: PsProfile,
    /// Per-job abuse limits: host-memory budget enforced at `Join`, spill
    /// caps, idle register reclamation, and re-serve rate limiting.
    pub limits: JobLimits,
    /// Downlink chaos injection point: run every server-sent datagram
    /// (GIA/aggregate multicasts, acks, re-serves) through a seeded
    /// [`ChaosLane`] — loss/dup/reorder/corruption on the server→client
    /// path without an external proxy. Lanes are per job, seeded from
    /// `chaos_seed ^ job_id`.
    pub downlink_chaos: Option<ChaosDirection>,
    /// Root seed for `downlink_chaos` lanes.
    pub chaos_seed: u64,
    /// Which I/O engine hosts the jobs (`--io` on the CLI; tests inherit
    /// the `FEDIAC_IO` environment variable through `Default`).
    pub io_backend: IoBackend,
    /// Reactor cores for the [`IoBackend::Fleet`] backend (`--cores` on
    /// the CLI). `0` (the default) sizes the fleet automatically:
    /// `min(available cores, 8)` where `SO_REUSEPORT` is native, one
    /// core elsewhere. Ignored by the single-socket backends.
    pub cores: usize,
    /// Host-memory accountant to charge job reservations against.
    /// `None` (the default) gives the daemon a private accountant with
    /// [`JobLimits::host_bytes`] per tenant; [`serve_sharded`] injects
    /// one shared accountant into every shard so a tenant's budget is
    /// global across the deployment.
    pub host_budget: Option<Arc<HostBudget>>,
    /// Flight recorder every hosted job and the dispatch path record
    /// protocol events into (`None`, the default, turns recording off —
    /// the hot path then pays one branch). The CLI's `--trace-dump`
    /// wires one in; wire tests attach one to dump the protocol
    /// timeline when they fail. Telemetry is observer-only: nothing on
    /// the wire changes either way (PROTOCOL.md §10).
    pub trace: Option<Arc<FlightRecorder>>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            bind: "127.0.0.1:0".to_string(),
            profile: PsProfile::high(),
            limits: JobLimits::default(),
            downlink_chaos: None,
            chaos_seed: 0,
            io_backend: IoBackend::from_env(),
            cores: 0,
            host_budget: None,
            trace: None,
        }
    }
}

/// Running daemon handle: address, live stats, shutdown. Single-socket
/// backends own one event thread and one stats block; the fleet backend
/// owns one of each per core, and [`ServerHandle::stats`] folds the
/// per-core blocks into one deployment view.
pub struct ServerHandle {
    pub(crate) addr: SocketAddr,
    /// One stats block per event thread (exactly one for the threaded
    /// and reactor backends; one per core for the fleet).
    pub(crate) per_core: Vec<Arc<ServerStats>>,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound socket address (useful with an ephemeral bind port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time copy of the daemon's counters — the K-way
    /// [`StatsSnapshot::merge`] of every core's block, so a fleet
    /// reports one deployment-wide view exactly like a single reactor.
    pub fn stats(&self) -> StatsSnapshot {
        let mut merged = StatsSnapshot::default();
        for s in &self.per_core {
            merged.merge(&s.snapshot());
        }
        merged
    }

    /// Per-core snapshots, index = core id (a single-element vector for
    /// the single-socket backends). This is the fleet's per-core
    /// telemetry surface: each entry carries that core's counters AND
    /// its own round-latency histograms, which `bench-wire` reports as
    /// per-core rounds/s and p99.
    pub fn per_core_stats(&self) -> Vec<StatsSnapshot> {
        self.per_core.iter().map(|s| s.snapshot()).collect()
    }

    /// Event threads backing this daemon (1 except for the fleet).
    pub fn cores(&self) -> usize {
        self.per_core.len()
    }

    /// Stop the event loop and join every backend thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Everything a backend loop needs besides the socket, bundled so the
/// two backends cannot drift apart on configuration plumbing.
pub(crate) struct BackendShared {
    pub(crate) profile: PsProfile,
    pub(crate) limits: JobLimits,
    pub(crate) chaos: Option<ChaosDirection>,
    pub(crate) chaos_seed: u64,
    pub(crate) stats: Arc<ServerStats>,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) budget: Arc<HostBudget>,
    pub(crate) recorder: Option<Arc<FlightRecorder>>,
}

/// Upper bound on concurrently hosted jobs (threaded: worker threads;
/// reactor: job slots). Jobs are born only on `Join` frames, and when
/// the cap is hit a job that never completed a valid `Join` (a forged or
/// abandoned id) is evicted first, so spraying job ids can neither spawn
/// unbounded state nor permanently lock new tenants out. The *policy*
/// (cap + evict-unconfigured-first) is normative for both backends; the
/// eviction *mechanics* are necessarily per-backend (the threaded one
/// joins a worker thread via its `configured` flag, the reactor drops
/// the slot after asking the job directly) — change them in lockstep.
pub(crate) const MAX_JOBS: usize = 256;

/// How long the threaded dispatch thread (and the reactor's sleep cap)
/// waits before re-checking the stop flag.
pub(crate) const STOP_POLL: Duration = Duration::from_millis(25);

/// Front-door reply for a datagram whose job id is not hosted. Genuine
/// uplink data kinds get the protocol's `JoinAck`/`UNKNOWN` nudge (the
/// client driver re-joins on seeing it); server-bound spoofs of downlink
/// kinds earn no reply at all — answering them would reflect traffic at
/// forged sources. Shared by both backends so the admission behaviour
/// cannot diverge.
pub(crate) fn unknown_job_reply(
    job_id: u32,
    kind: WireKind,
    stats: &ServerStats,
) -> Option<Vec<u8>> {
    if matches!(kind, WireKind::Vote | WireKind::Update | WireKind::Poll) {
        let h = Header::control(WireKind::JoinAck, job_id, u16::MAX, 0, JOIN_UNKNOWN_JOB);
        Some(encode_frame(&h, &[]))
    } else {
        ServerStats::bump(&stats.downlink_spoofs);
        None
    }
}

/// Record a front-door verdict — a datagram refused by the dispatch path
/// before any job saw it. `kind` is `None` for undecodable datagrams;
/// the round and client are unknown at this layer.
pub(crate) fn trace_front(
    rec: Option<&FlightRecorder>,
    job_id: u32,
    kind: Option<WireKind>,
    peer: SocketAddr,
    note: TraceNote,
    now: Instant,
) {
    if let Some(r) = rec {
        r.note(job_id, 0, kind, u16::MAX, Some(peer), note, now);
    }
}

/// Send one [`crate::server::JobOutput`]'s frames, through the job's
/// downlink chaos lane when one is attached. Send errors are ignored —
/// UDP semantics, the client's retransmission recovers. The frames are
/// borrowed, not consumed, so the caller can hand the buffers back to
/// the job's pool ([`crate::server::Job::recycle`]) afterwards. The
/// clean (no-chaos) path flushes through one
/// [`crate::net::poll::send_batch`] call — `sendmmsg(2)` bursts on
/// Linux (the kernel caps each call at UIO_MAXIOV and the wrapper
/// loops over the remainder), a plain send loop elsewhere.
pub(crate) fn transmit(
    socket: &UdpSocket,
    lane: &mut Option<ChaosLane<SocketAddr>>,
    frames: &Outgoing,
    now: Instant,
) {
    match lane.as_mut() {
        Some(l) => {
            for (bytes, dest) in frames {
                for (pkt, to) in l.process(bytes, *dest, now) {
                    let _ = socket.send_to(&pkt, to);
                }
            }
        }
        None => {
            let _ = crate::net::poll::send_batch(socket, frames);
        }
    }
}

/// Launch `n_shards` collaborating daemons in one process — shard `s` of
/// the deployment PROTOCOL.md §8 describes listens on `base.bind`'s port
/// plus `s` (an ephemeral port 0 in `base.bind` gives every shard its own
/// ephemeral port instead). Each shard is a full, independent
/// [`serve`] instance with its own socket, workers and stats — except
/// the host-memory accountant, which is **shared**: one
/// [`HostBudget`] (from `base.host_budget`, or a fresh one sized by
/// `base.limits.host_bytes`) is injected into every shard so a tenant's
/// budget bounds the whole deployment instead of multiplying by N.
/// Clients address shard `s` with a [`crate::wire::JobSpec`] whose
/// `shard` field names slice `s`. Returns one handle per shard, index =
/// shard id.
pub fn serve_sharded(base: &ServeOptions, n_shards: u8) -> io::Result<Vec<ServerHandle>> {
    if n_shards == 0 || n_shards > crate::wire::MAX_SHARDS {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "n_shards must be in [1, 16]",
        ));
    }
    let (host, port) = base
        .bind
        .rsplit_once(':')
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "bind must be host:port"))?;
    let port: u16 = port
        .parse()
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "bind port must be a u16"))?;
    let budget = base.host_budget.clone().unwrap_or_else(|| Arc::new(default_budget(base)));
    let mut handles = Vec::with_capacity(n_shards as usize);
    for s in 0..n_shards {
        let bind = if port == 0 {
            format!("{host}:0")
        } else {
            let p = port.checked_add(s as u16).ok_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidInput, "shard port range overflows u16")
            })?;
            format!("{host}:{p}")
        };
        let opts = ServeOptions {
            bind,
            // Decorrelate per-shard downlink chaos streams the same way
            // the proxy decorrelates per-flow lanes.
            chaos_seed: base.chaos_seed ^ ((s as u64) << 32),
            host_budget: Some(Arc::clone(&budget)),
            ..base.clone()
        };
        handles.push(serve(&opts)?);
    }
    Ok(handles)
}

/// The accountant a deployment gets when the caller injects none: the
/// fleet backend defaults to fair-share arbitration (many tenants on
/// many cores must not be starved first-come); the single-socket
/// backends keep first-come semantics.
pub(crate) fn default_budget(opts: &ServeOptions) -> HostBudget {
    if opts.io_backend == IoBackend::Fleet {
        HostBudget::new_fair(opts.limits.host_bytes)
    } else {
        HostBudget::new(opts.limits.host_bytes)
    }
}

/// Bind a socket and start the selected I/O backend.
pub fn serve(opts: &ServeOptions) -> io::Result<ServerHandle> {
    if opts.io_backend == IoBackend::Fleet {
        // The fleet binds its own SO_REUSEPORT socket group (the option
        // must be set before any bind, so the plain bind below would
        // poison the port for the member sockets).
        return fleet::serve_fleet(opts);
    }
    let socket = UdpSocket::bind(&opts.bind)?;
    let addr = socket.local_addr()?;
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));
    let shared = BackendShared {
        profile: opts.profile.clone(),
        limits: opts.limits,
        chaos: opts.downlink_chaos,
        chaos_seed: opts.chaos_seed,
        stats: Arc::clone(&stats),
        stop: Arc::clone(&stop),
        budget: opts.host_budget.clone().unwrap_or_else(|| Arc::new(default_budget(opts))),
        recorder: opts.trace.clone(),
    };
    crate::debug!("bound {addr} backend={}", opts.io_backend.name());
    let dispatch = match opts.io_backend {
        IoBackend::Threaded => {
            socket.set_read_timeout(Some(STOP_POLL))?;
            thread::Builder::new()
                .name("fediac-dispatch".into())
                .spawn(move || threaded::dispatch_loop(socket, shared))?
        }
        IoBackend::Reactor => {
            socket.set_nonblocking(true)?;
            thread::Builder::new()
                .name("fediac-reactor".into())
                .spawn(move || reactor::reactor_loop(socket, shared))?
        }
        IoBackend::Fleet => unreachable!("handled above"),
    };

    Ok(ServerHandle { addr, per_core: vec![stats], stop, threads: vec![dispatch] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{decode_frame, encode_frame, Header, JobSpec, ShardPlan, WireKind};

    fn opts_for(backend: IoBackend) -> ServeOptions {
        ServeOptions { io_backend: backend, ..ServeOptions::default() }
    }

    fn join_spec() -> JobSpec {
        JobSpec {
            d: 64,
            n_clients: 1,
            threshold_a: 1,
            payload_budget: 8,
            shard: ShardPlan::single(),
            quorum: 0,
        }
    }

    fn daemon_smoke(backend: IoBackend) {
        let handle = serve(&opts_for(backend)).unwrap();
        let addr = handle.local_addr();

        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let spec = join_spec();
        let join = encode_frame(&Header::control(WireKind::Join, 5, 0, 0, 0), &spec.encode());
        client.send_to(&join, addr).unwrap();

        let mut buf = [0u8; 2048];
        let (n, _) = client.recv_from(&mut buf).unwrap();
        let frame = decode_frame(&buf[..n]).unwrap();
        assert_eq!(frame.header.kind, WireKind::JoinAck);
        assert_eq!(frame.header.aux, crate::server::JOIN_OK);

        // Garbage is counted, not fatal.
        client.send_to(b"not a frame", addr).unwrap();
        // A second job is hosted alongside the first.
        let join2 = encode_frame(&Header::control(WireKind::Join, 6, 0, 0, 0), &spec.encode());
        client.send_to(&join2, addr).unwrap();
        let (n, _) = client.recv_from(&mut buf).unwrap();
        assert_eq!(decode_frame(&buf[..n]).unwrap().header.job, 6);

        // A data frame for a job nobody joined is answered straight from
        // the front door — no job slot is spent on it.
        let stray = encode_frame(
            &Header {
                kind: WireKind::Vote,
                client: 0,
                job: 999,
                round: 0,
                block: 0,
                n_blocks: 1,
                elems: 8,
                aux: 0,
            },
            &[0u8; 1],
        );
        client.send_to(&stray, addr).unwrap();
        let (n, _) = client.recv_from(&mut buf).unwrap();
        let f = decode_frame(&buf[..n]).unwrap();
        assert_eq!(f.header.kind, WireKind::JoinAck);
        assert_eq!(f.header.job, 999);
        assert_eq!(f.header.aux, crate::server::JOIN_UNKNOWN_JOB);

        // A server-bound spoof of a *downlink* kind gets no reply at all
        // (a JoinAck echo here would be reflection fodder).
        let spoof = encode_frame(
            &Header {
                kind: WireKind::Gia,
                client: u16::MAX,
                job: 31337,
                round: 0,
                block: 0,
                n_blocks: 1,
                elems: 0,
                aux: 0,
            },
            &[],
        );
        client.send_to(&spoof, addr).unwrap();
        client.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        let mut tmp = [0u8; 64];
        assert!(client.recv_from(&mut tmp).is_err(), "spoofed downlink frame was answered");

        let stats = handle.stats();
        assert!(stats.packets >= 3);
        assert_eq!(stats.jobs_created, 2);
        assert!(stats.decode_errors >= 1);
        assert!(stats.downlink_spoofs >= 1);
        match backend {
            IoBackend::Threaded => assert_eq!(stats.workers_spawned, 2),
            IoBackend::Reactor | IoBackend::Fleet => assert_eq!(stats.workers_spawned, 0),
        }
        handle.shutdown();
    }

    #[test]
    fn threaded_daemon_starts_acks_join_and_shuts_down() {
        daemon_smoke(IoBackend::Threaded);
    }

    #[test]
    fn reactor_daemon_starts_acks_join_and_shuts_down() {
        daemon_smoke(IoBackend::Reactor);
    }

    #[test]
    fn fleet_daemon_starts_acks_join_and_shuts_down() {
        daemon_smoke(IoBackend::Fleet);
    }

    #[test]
    fn fleet_daemon_shares_one_fair_budget_across_cores() {
        // Without an injected accountant the fleet builds a fair-share
        // one and shares the single Arc across every core: a tenant
        // admitted once must be refused a second over-budget Join even
        // when the two Joins land on (and are owned by) different cores.
        let spec = JobSpec {
            d: 10_000,
            n_clients: 2,
            threshold_a: 1,
            payload_budget: 8,
            shard: ShardPlan::single(),
            quorum: 0,
        };
        let worst_fits_once =
            spec.host_bytes_per_round() * crate::server::job::MAX_LIVE_ROUNDS + 1024;
        let budget = Arc::new(HostBudget::new_fair(worst_fits_once));
        let handle = serve(&ServeOptions {
            limits: JobLimits { host_bytes: worst_fits_once, ..JobLimits::default() },
            io_backend: IoBackend::Fleet,
            cores: 4,
            host_budget: Some(Arc::clone(&budget)),
            ..ServeOptions::default()
        })
        .unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut statuses = Vec::new();
        // Job ids spread across owner cores; each is a separate tenant,
        // so under the deployment-wide budget only the first fits.
        for job in [40u32, 41] {
            let join =
                encode_frame(&Header::control(WireKind::Join, job, 0, 0, 0), &spec.encode());
            client.send_to(&join, handle.local_addr()).unwrap();
            let mut buf = [0u8; 256];
            let (n, _) = client.recv_from(&mut buf).unwrap();
            statuses.push(decode_frame(&buf[..n]).unwrap().header.aux);
        }
        assert_eq!(statuses[0], crate::server::JOIN_OK, "first tenant must admit");
        assert_eq!(
            statuses[1],
            crate::server::JOIN_BAD_SPEC,
            "second tenant must see the shared budget spent"
        );
        handle.shutdown();
        // Post-shutdown the shared accountant returns to zero: every
        // core released what its jobs reserved.
        for job in [40u32, 41] {
            assert_eq!(budget.reserved(job), 0, "job {job} leaked budget");
        }
    }

    #[test]
    fn sharded_daemons_bind_and_ack_shard_specs() {
        let handles = serve_sharded(&ServeOptions::default(), 2).unwrap();
        assert_eq!(handles.len(), 2);
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        for (s, h) in handles.iter().enumerate() {
            let spec = JobSpec {
                d: 64,
                n_clients: 1,
                threshold_a: 1,
                payload_budget: 8,
                shard: ShardPlan { n_shards: 2, shard_id: s as u8 },
                quorum: 0,
            };
            let join =
                encode_frame(&Header::control(WireKind::Join, 11, 0, 0, 0), &spec.encode());
            client.send_to(&join, h.local_addr()).unwrap();
            let mut buf = [0u8; 256];
            let (n, _) = client.recv_from(&mut buf).unwrap();
            let f = decode_frame(&buf[..n]).unwrap();
            assert_eq!(f.header.kind, WireKind::JoinAck);
            assert_eq!(f.header.aux, crate::server::JOIN_OK, "shard {s} refused its spec");
        }
        assert_ne!(handles[0].local_addr(), handles[1].local_addr());
        for h in handles {
            h.shutdown();
        }
    }

    #[test]
    fn sharded_serve_rejects_bad_shard_counts() {
        assert!(serve_sharded(&ServeOptions::default(), 0).is_err());
        assert!(serve_sharded(&ServeOptions::default(), 17).is_err());
    }

    #[test]
    fn sharded_serve_shares_one_host_budget() {
        // A tenant whose per-shard worst case fits the budget once must
        // not get it N times over: the same job joining both shards is
        // admitted on the first and refused on the second. The budget is
        // sized to one reservation + slack so the order of shard joins
        // cannot matter.
        let spec = JobSpec {
            d: 10_000,
            n_clients: 2,
            threshold_a: 1,
            payload_budget: 8,
            shard: ShardPlan { n_shards: 2, shard_id: 0 },
            quorum: 0,
        };
        let worst_fits_once =
            spec.host_bytes_per_round() * crate::server::job::MAX_LIVE_ROUNDS + 1024;
        let base = ServeOptions {
            limits: JobLimits { host_bytes: worst_fits_once, ..JobLimits::default() },
            ..ServeOptions::default()
        };
        let handles = serve_sharded(&base, 2).unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut statuses = Vec::new();
        for (s, h) in handles.iter().enumerate() {
            let shard_spec =
                JobSpec { shard: ShardPlan { n_shards: 2, shard_id: s as u8 }, ..spec };
            let join = encode_frame(
                &Header::control(WireKind::Join, 21, 0, 0, 0),
                &shard_spec.encode(),
            );
            client.send_to(&join, h.local_addr()).unwrap();
            let mut buf = [0u8; 256];
            let (n, _) = client.recv_from(&mut buf).unwrap();
            statuses.push(decode_frame(&buf[..n]).unwrap().header.aux);
        }
        assert_eq!(statuses[0], crate::server::JOIN_OK, "first shard must admit");
        assert_eq!(
            statuses[1],
            crate::server::JOIN_BAD_SPEC,
            "second shard must see the tenant's deployment-wide budget spent"
        );
        for h in handles {
            h.shutdown();
        }
    }

    fn downlink_chaos_drop(backend: IoBackend) {
        // Full downlink drop: the JoinAck never escapes the daemon.
        let handle = serve(&ServeOptions {
            downlink_chaos: Some(ChaosDirection::lossy(1.0, 0.0, 0.0)),
            chaos_seed: 5,
            io_backend: backend,
            ..ServeOptions::default()
        })
        .unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
        let join =
            encode_frame(&Header::control(WireKind::Join, 8, 0, 0, 0), &join_spec().encode());
        client.send_to(&join, handle.local_addr()).unwrap();
        let mut buf = [0u8; 256];
        assert!(client.recv_from(&mut buf).is_err(), "dropped JoinAck arrived");
        assert_eq!(handle.stats().joins, 1, "join itself must still register");
        handle.shutdown();
    }

    #[test]
    fn downlink_chaos_lane_reaches_threaded_sends() {
        downlink_chaos_drop(IoBackend::Threaded);
    }

    #[test]
    fn downlink_chaos_lane_reaches_reactor_sends() {
        downlink_chaos_drop(IoBackend::Reactor);
    }

    #[test]
    fn downlink_chaos_lane_reaches_fleet_sends() {
        downlink_chaos_drop(IoBackend::Fleet);
    }

    fn idle_reclaim_without_traffic(backend: IoBackend) {
        // One vote block of a two-block round stalls a job with resident
        // registers; the backend must reclaim them off the job's OWN
        // timer deadline — no follow-up traffic, no fixed polling tick.
        let handle = serve(&ServeOptions {
            profile: PsProfile { memory_bytes: 1 << 20, ..PsProfile::high() },
            limits: JobLimits {
                idle_release_after: Duration::from_millis(100),
                ..JobLimits::default()
            },
            io_backend: backend,
            ..ServeOptions::default()
        })
        .unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let spec = JobSpec {
            d: 128,
            n_clients: 2,
            threshold_a: 2,
            payload_budget: 8,
            shard: ShardPlan::single(),
            quorum: 0,
        };
        let join = encode_frame(&Header::control(WireKind::Join, 9, 0, 0, 0), &spec.encode());
        client.send_to(&join, handle.local_addr()).unwrap();
        let mut buf = [0u8; 256];
        let (n, _) = client.recv_from(&mut buf).unwrap();
        assert_eq!(decode_frame(&buf[..n]).unwrap().header.aux, crate::server::JOIN_OK);
        // One valid vote block (of 2) allocates a wave, then silence.
        let vote = encode_frame(
            &Header {
                kind: WireKind::Vote,
                client: 0,
                job: 9,
                round: 0,
                block: 0,
                n_blocks: 2,
                elems: 64,
                aux: 1.0f32.to_bits(),
            },
            &[0xFFu8; 8],
        );
        client.send_to(&vote, handle.local_addr()).unwrap();
        // Wait past the idle deadline with zero traffic.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            let s = handle.stats();
            if s.idle_releases >= 1 {
                assert!(s.idle_wakeups >= 1, "reclaim must come from a timer wakeup");
                // The fix's point: a deadline-driven backend wakes a
                // handful of times, not once per polling tick.
                assert!(
                    s.idle_wakeups <= 8,
                    "{} idle wakeups — backend is busy-polling",
                    s.idle_wakeups
                );
                break;
            }
            assert!(Instant::now() < deadline, "idle registers never reclaimed");
            std::thread::sleep(Duration::from_millis(20));
        }
        handle.shutdown();
    }

    #[test]
    fn threaded_idle_reclaim_is_timer_driven() {
        idle_reclaim_without_traffic(IoBackend::Threaded);
    }

    #[test]
    fn reactor_idle_reclaim_is_timer_driven() {
        idle_reclaim_without_traffic(IoBackend::Reactor);
    }

    #[test]
    fn fleet_idle_reclaim_is_timer_driven() {
        // Only the owning core arms the job's timer, so the wakeup
        // budget holds even with several cores sleeping alongside.
        idle_reclaim_without_traffic(IoBackend::Fleet);
    }
}
