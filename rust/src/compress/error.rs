//! Empirical compression-error measurement.
//!
//! γ̂ = ‖Π(Θ(f·U)) − f·U‖² / ‖f·U‖² — the quantity Proposition 1 bounds.
//! Experiments compare this Monte-Carlo estimate against the analytic γ
//! from `theory::prop1` (E7) and the convergence requirement 0 < γ < 1.

/// Relative squared compression error of one client's round.
pub fn relative_error(q: &[i32], updates: &[f32], f: f32) -> f64 {
    debug_assert_eq!(q.len(), updates.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..q.len() {
        let target = updates[i] as f64 * f as f64;
        let got = q[i] as f64;
        num += (got - target) * (got - target);
        den += target * target;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::{max_abs, quantize_sparsify, scale_factor};
    use crate::util::{prop, Rng};

    #[test]
    fn zero_error_when_everything_kept_and_integral() {
        let updates = vec![1.0f32, -2.0, 3.0];
        let q = vec![2, -4, 6];
        assert_eq!(relative_error(&q, &updates, 2.0), 0.0);
    }

    #[test]
    fn full_mask_error_below_one() {
        // With everything uploaded, only rounding error remains: γ̂ ≪ 1.
        let mut rng = Rng::new(3);
        let updates = prop::gen_updates(&mut rng, 4096, 0.05);
        let mask = vec![1.0f32; 4096];
        let f = scale_factor(12, 20, max_abs(&updates));
        let (q, _) = quantize_sparsify(&updates, &mask, f, &mut rng);
        let g = relative_error(&q, &updates, f);
        assert!(g < 0.05, "γ̂ {g}");
    }

    #[test]
    fn empty_mask_error_is_one() {
        // Nothing uploaded ⇒ the full signal is lost: γ̂ = 1.
        let mut rng = Rng::new(4);
        let updates = prop::gen_updates(&mut rng, 1024, 0.05);
        let mask = vec![0.0f32; 1024];
        let f = scale_factor(12, 20, max_abs(&updates));
        let (q, _) = quantize_sparsify(&updates, &mask, f, &mut rng);
        let g = relative_error(&q, &updates, f);
        assert!((g - 1.0).abs() < 1e-9, "γ̂ {g}");
    }

    #[test]
    fn error_decreases_with_mask_coverage() {
        let mut rng = Rng::new(5);
        let updates = prop::gen_updates(&mut rng, 2048, 0.05);
        let f = scale_factor(12, 20, max_abs(&updates));
        let gamma_at = |frac: f64, rng: &mut Rng| {
            let mask: Vec<f32> = (0..2048)
                .map(|i| if (i as f64 / 2048.0) < frac { 1.0 } else { 0.0 })
                .collect();
            let (q, _) = quantize_sparsify(&updates, &mask, f, rng);
            relative_error(&q, &updates, f)
        };
        let g20 = gamma_at(0.2, &mut rng);
        let g80 = gamma_at(0.8, &mut rng);
        assert!(g80 < g20, "g80 {g80} vs g20 {g20}");
    }
}
