//! Run-length encoding for 0-1 index arrays (§IV-D future work).
//!
//! "For extremely high-dimension models ... we should explore compression
//! techniques such as run-length encoding (which are particularly
//! effective in compressing 0-1 arrays) to shrink the size of index arrays
//! in Phase 1." [33]
//!
//! Format: alternating run lengths starting with a 0-run, each length
//! LEB128-varint encoded. Sparse k≪d vote bitmaps compress to roughly
//! k·(varint gap) bytes instead of d/8.

use crate::util::BitVec;

/// LEB128 varint append.
fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Option<u64> {
    let mut shift = 0u32;
    let mut v = 0u64;
    loop {
        let byte = *bytes.get(*pos)?;
        *pos += 1;
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

/// Encode a bitmap as alternating 0-run/1-run lengths (first run may be 0
/// if the bitmap starts with a 1).
pub fn encode(bv: &BitVec) -> Vec<u8> {
    let mut out = Vec::new();
    push_varint(&mut out, bv.len() as u64);
    let mut current = false; // runs start with 0s
    let mut run: u64 = 0;
    for i in 0..bv.len() {
        let bit = bv.get(i);
        if bit == current {
            run += 1;
        } else {
            push_varint(&mut out, run);
            current = bit;
            run = 1;
        }
    }
    push_varint(&mut out, run);
    out
}

/// Decode back to a bitmap. Returns None on malformed input.
pub fn decode(bytes: &[u8]) -> Option<BitVec> {
    let mut pos = 0usize;
    let len = read_varint(bytes, &mut pos)? as usize;
    let mut bv = BitVec::zeros(len);
    let mut i = 0usize;
    let mut current = false;
    while i < len {
        let run = read_varint(bytes, &mut pos)? as usize;
        if current {
            for j in i..(i + run).min(len) {
                bv.set(j, true);
            }
        }
        i += run;
        current = !current;
    }
    if i != len {
        return None;
    }
    Some(bv)
}

/// Encoded size without materialising the buffer (traffic accounting).
pub fn encoded_bytes(bv: &BitVec) -> usize {
    encode(bv).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn roundtrip_simple() {
        for pattern in [
            vec![],
            vec![0usize],
            vec![4],
            vec![0, 1, 2, 3, 4],
            vec![0, 2, 4],
        ] {
            let bv = BitVec::from_indices(5, &pattern);
            let enc = encode(&bv);
            assert_eq!(decode(&enc).unwrap(), bv, "pattern {pattern:?}");
        }
    }

    #[test]
    fn roundtrip_property() {
        prop::check("rle_roundtrip", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let density = rng.f64();
            let mut bv = BitVec::zeros(d);
            for i in 0..d {
                if rng.f64() < density {
                    bv.set(i, true);
                }
            }
            let dec = decode(&encode(&bv)).ok_or("decode failed")?;
            crate::prop_assert!(dec == bv, "roundtrip mismatch d={d}");
            Ok(())
        });
    }

    #[test]
    fn sparse_bitmaps_compress_well() {
        // 5% density over 100k dims: RLE beats the raw 12.5 kB bitmap.
        let d = 100_000;
        let mut rng = Rng::new(9);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..d / 20]);
        let raw = bv.payload_bytes();
        let rle = encoded_bytes(&bv);
        assert!(rle < raw, "rle {rle} >= raw {raw}");
    }

    #[test]
    fn dense_bitmaps_fall_back_gracefully() {
        // Near-50% density is RLE's worst case; it may expand but must
        // still round-trip (callers pick min(raw, rle) for the wire).
        let d = 4096;
        let mut rng = Rng::new(10);
        let mut bv = BitVec::zeros(d);
        for i in 0..d {
            if rng.f64() < 0.5 {
                bv.set(i, true);
            }
        }
        assert_eq!(decode(&encode(&bv)).unwrap(), bv);
    }

    #[test]
    fn malformed_input_rejected() {
        assert!(decode(&[]).is_none());
        // Claims 100 bits but provides runs for only 3.
        let mut bytes = Vec::new();
        push_varint(&mut bytes, 100);
        push_varint(&mut bytes, 3);
        assert!(decode(&bytes).is_none());
    }
}
