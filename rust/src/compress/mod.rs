//! Compression substrate: Eq.-1 stochastic quantisation, magnitude-
//! proportional voting, Topk, GIA deduction, RLE index-array coding and
//! empirical compression-error measurement.

pub mod error;
pub mod gia;
pub mod golomb;
pub mod quantize;
pub mod rle;
pub mod topk;
pub mod vote;

pub use gia::deduce_gia;
pub use quantize::{
    dequantize_aggregate, max_abs, quantize_dense, quantize_sparsify, scale_factor,
};
pub use topk::{topk_by_magnitude, topk_mask, topk_sparse};
pub use vote::{top_k_indices, vote_bitmap, vote_bitmap_from_scores, vote_scores_native};
