//! Magnitude-proportional voting (§IV step 1) + top-k selection.
//!
//! "Client i probabilistically votes k elements. The odds to vote each
//! model update is proportional to its magnitude." Sampling k indices
//! without replacement with probability ∝ |U_l| is realised by the
//! Gumbel-top-k identity: perturb log|U_l| with Gumbel(0,1) noise and take
//! the k largest scores. The PJRT backend computes scores with the Pallas
//! `vote` artifact; this module provides the native scorer plus the
//! top-k selector both backends share (selection stays in rust so k is a
//! runtime parameter).

use crate::util::{BitVec, Rng};

/// Native Gumbel vote scores (semantics mirror kernels/vote_kernel.py).
///
/// Perf: top-k only cares about the *ordering*, and
/// log|u| + Gumbel = log|u| − log(−log U) = log(|u| / E) with
/// E = −log U ~ Exp(1), so we return the monotone-equivalent linear-domain
/// score |u|/E — one `ln` per element instead of three. This is exactly
/// the exponential-race formulation of Gumbel-top-k (identical selection
/// distribution); EXPERIMENTS.md §Perf records the 2.3× speedup.
pub fn vote_scores_native(updates: &[f32], rng: &mut Rng) -> Vec<f32> {
    updates
        .iter()
        .map(|&u| {
            let e = -(rng.f64_open().ln()) as f32; // Exp(1)
            (u.abs() + 1e-30) / e
        })
        .collect()
}

/// Indices of the k largest scores (unordered). O(d) quickselect + final
/// partition; the hot path for every client every round.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let d = scores.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= d {
        return (0..d).collect();
    }
    let mut idx: Vec<u32> = (0..d as u32).collect();
    // Quickselect on scores so that the top-k occupy idx[..k].
    let mut lo = 0usize;
    let mut hi = d;
    let target = k;
    let mut state = 0x9E3779B97F4A7C15u64 ^ (d as u64);
    while hi - lo > 1 {
        // Deterministic pseudo-random pivot to dodge adversarial patterns.
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let pivot_pos = lo + (state as usize) % (hi - lo);
        let pivot = scores[idx[pivot_pos] as usize];
        // Partition: larger-than-pivot first.
        let mut i = lo;
        let mut j = hi - 1;
        while i <= j {
            while scores[idx[i] as usize] > pivot {
                i += 1;
            }
            while scores[idx[j] as usize] < pivot {
                if j == 0 {
                    break;
                }
                j -= 1;
            }
            if i <= j {
                idx.swap(i, j);
                i += 1;
                if j == 0 {
                    break;
                }
                j -= 1;
            }
        }
        if target <= j + 1 {
            hi = j + 1;
        } else if target >= i {
            lo = i;
        } else {
            break; // pivot band covers position k
        }
    }
    idx.truncate(d);
    let mut out: Vec<usize> = idx[..k].iter().map(|&i| i as usize).collect();
    out.sort_unstable();
    out
}

/// One client's vote: k Gumbel-top-k indices as a packed bitmap.
pub fn vote_bitmap(updates: &[f32], k: usize, rng: &mut Rng) -> BitVec {
    let scores = vote_scores_native(updates, rng);
    vote_bitmap_from_scores(&scores, k)
}

/// Build the vote bitmap from externally computed scores (PJRT path).
pub fn vote_bitmap_from_scores(scores: &[f32], k: usize) -> BitVec {
    let idx = top_k_indices(scores, k);
    BitVec::from_indices(scores.len(), &idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn top_k_small_exact() {
        let scores = vec![0.1, 5.0, -1.0, 3.0, 4.0];
        assert_eq!(top_k_indices(&scores, 2), vec![1, 4]);
        assert_eq!(top_k_indices(&scores, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&scores, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(top_k_indices(&scores, 9), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn top_k_matches_sort_reference() {
        prop::check("topk_vs_sort", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let scores = prop::gen_updates(rng, d, 1.0);
            let k = rng.below(d + 1);
            let got = top_k_indices(&scores, k);
            // Reference: full sort by (score desc, index asc is irrelevant —
            // compare the selected score multiset instead to allow ties).
            let mut by_score: Vec<usize> = (0..d).collect();
            by_score.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let mut want: Vec<f32> = by_score[..k].iter().map(|&i| scores[i]).collect();
            let mut have: Vec<f32> = got.iter().map(|&i| scores[i]).collect();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            have.sort_by(|a, b| a.partial_cmp(b).unwrap());
            crate::prop_assert!(got.len() == k.min(d), "size {} != {}", got.len(), k);
            crate::prop_assert!(want == have, "selected multiset mismatch d={d} k={k}");
            Ok(())
        });
    }

    #[test]
    fn vote_prefers_large_magnitudes() {
        let mut rng = Rng::new(5);
        let d = 200;
        let mut updates = vec![0.001f32; d];
        updates.iter_mut().take(10).for_each(|u| *u = 10.0);
        let mut hits = vec![0usize; d];
        let trials = 200;
        for _ in 0..trials {
            for i in vote_bitmap(&updates, 20, &mut rng).iter_ones() {
                hits[i] += 1;
            }
        }
        assert!(hits[..10].iter().all(|&h| h as f64 >= 0.95 * trials as f64));
        let rest: f64 =
            hits[10..].iter().sum::<usize>() as f64 / (d - 10) as f64 / trials as f64;
        assert!(rest < 0.2, "background hit rate {rest}");
    }

    #[test]
    fn vote_bitmap_has_exactly_k_bits() {
        let mut rng = Rng::new(6);
        let updates = prop::gen_updates(&mut rng, 1000, 0.1);
        for k in [0usize, 1, 50, 1000] {
            assert_eq!(vote_bitmap(&updates, k, &mut rng).count_ones(), k.min(1000));
        }
    }

    #[test]
    fn ties_handled() {
        let scores = vec![1.0f32; 64];
        let got = top_k_indices(&scores, 10);
        assert_eq!(got.len(), 10);
        let mut uniq = got.clone();
        uniq.dedup();
        assert_eq!(uniq.len(), 10);
    }
}
