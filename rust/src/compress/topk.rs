//! Deterministic Topk sparsification [13] — the compression primitive the
//! libra and OmniReduce baselines are built on (§V-A3: both "will be
//! sparsified using Topk before uploading").

use crate::compress::vote::top_k_indices;
use crate::util::BitVec;

/// Indices of the k largest-|v| entries (ascending index order).
pub fn topk_by_magnitude(values: &[f32], k: usize) -> Vec<usize> {
    let mags: Vec<f32> = values.iter().map(|v| v.abs()).collect();
    top_k_indices(&mags, k)
}

/// Topk selection as a mask bitmap.
pub fn topk_mask(values: &[f32], k: usize) -> BitVec {
    BitVec::from_indices(values.len(), &topk_by_magnitude(values, k))
}

/// Sparse (index, value) pairs for the k largest-|v| entries.
pub fn topk_sparse(values: &[f32], k: usize) -> Vec<(usize, f32)> {
    topk_by_magnitude(values, k).into_iter().map(|i| (i, values[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_largest_magnitudes() {
        let v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        assert_eq!(topk_by_magnitude(&v, 2), vec![1, 3]);
        let sparse = topk_sparse(&v, 2);
        assert_eq!(sparse, vec![(1, -5.0), (3, 3.0)]);
    }

    #[test]
    fn mask_matches_indices() {
        let v = vec![1.0, -2.0, 0.5, 4.0];
        let mask = topk_mask(&v, 2);
        assert_eq!(mask.iter_ones().collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(mask.count_ones(), 2);
    }

    #[test]
    fn k_larger_than_d() {
        let v = vec![1.0, 2.0];
        assert_eq!(topk_by_magnitude(&v, 10), vec![0, 1]);
    }
}
