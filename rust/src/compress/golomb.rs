//! Golomb–Rice coding of 0-1 index arrays — the second §IV-D candidate.
//!
//! A sparse vote bitmap is a sequence of gaps between set bits; for k
//! random votes over d dimensions the gaps are ≈ geometric with mean
//! d/k, for which Golomb coding with M ≈ 0.69·d/k is the optimal prefix
//! code. We use the Rice restriction (M = 2^r) for cheap shifts — the
//! same trade-off a switch/NIC implementation would make.
//!
//! `bench_compress` (E8) compares raw bitmap vs RLE vs Golomb–Rice.

use crate::util::BitVec;

/// Bit-granular writer.
struct BitWriter {
    bytes: Vec<u8>,
    bit: u8,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), bit: 0 }
    }

    fn push_bit(&mut self, b: bool) {
        if self.bit == 0 {
            self.bytes.push(0);
        }
        if b {
            *self.bytes.last_mut().unwrap() |= 1 << self.bit;
        }
        self.bit = (self.bit + 1) & 7;
    }

    fn push_bits(&mut self, value: u64, n: u32) {
        for i in (0..n).rev() {
            self.push_bit((value >> i) & 1 == 1);
        }
    }

    fn finish(self) -> Vec<u8> {
        self.bytes
    }
}

/// Bit-granular reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    fn read_bit(&mut self) -> Option<bool> {
        let byte = *self.bytes.get(self.pos >> 3)?;
        let b = (byte >> (self.pos & 7)) & 1 == 1;
        self.pos += 1;
        Some(b)
    }

    fn read_bits(&mut self, n: u32) -> Option<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.read_bit()? as u64;
        }
        Some(v)
    }
}

/// Rice parameter r chosen from the density: M = 2^r ≈ 0.69·d/k.
pub fn rice_param(d: usize, ones: usize) -> u32 {
    if ones == 0 || d == 0 {
        return 0;
    }
    let target = 0.69 * d as f64 / ones as f64;
    target.max(1.0).log2().round().clamp(0.0, 32.0) as u32
}

/// Encode: header (d, count, r as LEB128-ish u32s) + Rice-coded gaps.
pub fn encode(bv: &BitVec) -> Vec<u8> {
    let ones: Vec<usize> = bv.iter_ones().collect();
    let r = rice_param(bv.len(), ones.len());
    let mut w = BitWriter::new();
    w.push_bits(bv.len() as u64, 32);
    w.push_bits(ones.len() as u64, 32);
    w.push_bits(r as u64, 6);
    let mut prev = 0usize;
    for (i, &idx) in ones.iter().enumerate() {
        let gap = if i == 0 { idx } else { idx - prev - 1 } as u64;
        prev = idx;
        // Rice: quotient unary + r remainder bits.
        let q = gap >> r;
        for _ in 0..q {
            w.push_bit(true);
        }
        w.push_bit(false);
        w.push_bits(gap & ((1u64 << r) - 1).max(0), r);
    }
    w.finish()
}

/// Decode; None on malformed input. The declared dimension is untrusted
/// input — callers that know the expected model dimension should prefer
/// [`decode_with_limit`], which also bounds the output allocation.
pub fn decode(bytes: &[u8]) -> Option<BitVec> {
    decode_with_limit(bytes, u32::MAX as usize)
}

/// Decode with an upper bound on the declared dimension. A mutated or
/// forged stream can claim any 32-bit `d`; without a cap that is a
/// 512 MB allocation per call. The wire client passes its own `d`, so a
/// stream that disagrees is rejected before any allocation.
pub fn decode_with_limit(bytes: &[u8], max_d: usize) -> Option<BitVec> {
    let mut rd = BitReader { bytes, pos: 0 };
    let d = rd.read_bits(32)? as usize;
    let count = rd.read_bits(32)? as usize;
    let r = rd.read_bits(6)? as u32;
    if d > max_d || count > d {
        return None;
    }
    // Every coded index costs at least one bit, so `count` beyond the
    // remaining input length is malformed — and, pre-check, a forged
    // count near 2^32 would otherwise spin this loop for minutes.
    if count > bytes.len().saturating_mul(8) {
        return None;
    }
    let mut bv = BitVec::zeros(d);
    let mut prev: Option<usize> = None;
    for _ in 0..count {
        let mut q = 0u64;
        loop {
            match rd.read_bit()? {
                true => q += 1,
                false => break,
            }
            if q as usize > d {
                return None;
            }
        }
        let rem = rd.read_bits(r)?;
        // `q << r` would silently discard high bits for q ≥ 2^(64−r),
        // letting a forged stream alias an astronomical gap down to an
        // attacker-chosen small one — reject before shifting.
        if r > 0 && q >= 1u64 << (64 - r) {
            return None;
        }
        let gap = (q << r) | rem;
        // Any legal gap is < d (indices are strictly increasing below d);
        // checking before the index arithmetic also keeps `prev + 1 + gap`
        // from overflowing on adversarial (q, r) combinations.
        if gap >= d as u64 {
            return None;
        }
        let idx = match prev {
            None => gap as usize,
            Some(p) => p + 1 + gap as usize,
        };
        if idx >= d {
            return None;
        }
        bv.set(idx, true);
        prev = Some(idx);
    }
    Some(bv)
}

/// Encoded size in bytes.
pub fn encoded_bytes(bv: &BitVec) -> usize {
    encode(bv).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn roundtrip_simple_patterns() {
        for pattern in [
            vec![],
            vec![0usize],
            vec![9],
            vec![0, 1, 2],
            vec![0, 5, 9],
            (0..10).collect::<Vec<_>>(),
        ] {
            let bv = BitVec::from_indices(10, &pattern);
            assert_eq!(decode(&encode(&bv)).unwrap(), bv, "{pattern:?}");
        }
    }

    #[test]
    fn roundtrip_property() {
        prop::check("golomb_roundtrip", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let density = rng.f64() * rng.f64(); // biased sparse
            let mut bv = BitVec::zeros(d);
            for i in 0..d {
                if rng.f64() < density {
                    bv.set(i, true);
                }
            }
            let dec = decode(&encode(&bv)).ok_or("decode failed")?;
            crate::prop_assert!(dec == bv, "golomb roundtrip d={d}");
            Ok(())
        });
    }

    #[test]
    fn sparse_votes_beat_raw_bitmap() {
        let d = 100_000;
        let k = d / 20; // the paper's 5% vote density
        let mut rng = Rng::new(11);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..k]);
        let raw = bv.payload_bytes();
        let gol = encoded_bytes(&bv);
        assert!(gol < raw, "golomb {gol} >= raw {raw}");
    }

    #[test]
    fn golomb_beats_rle_on_random_sparse() {
        // Random (geometric-gap) patterns are Golomb's sweet spot; RLE
        // wins only on long literal runs.
        use crate::compress::rle;
        let d = 50_000;
        let mut rng = Rng::new(12);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..d / 50]);
        let gol = encoded_bytes(&bv);
        let r = rle::encoded_bytes(&bv);
        assert!(gol <= r, "golomb {gol} > rle {r} on random sparse");
    }

    #[test]
    fn rice_param_tracks_density() {
        assert!(rice_param(100_000, 50_000) < rice_param(100_000, 1_000));
        assert_eq!(rice_param(100, 0), 0);
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode(&[]).is_none());
        let enc = encode(&BitVec::from_indices(100, &[3, 50]));
        assert!(decode(&enc[..enc.len() - 1]).is_none());
    }

    /// Craft a raw stream: header (d, count, r) + explicit payload bits.
    fn craft(d: u64, count: u64, r: u32, body: &[bool]) -> Vec<u8> {
        let mut w = BitWriter::new();
        w.push_bits(d, 32);
        w.push_bits(count, 32);
        w.push_bits(r as u64, 6);
        for &b in body {
            w.push_bit(b);
        }
        w.finish()
    }

    #[test]
    fn forged_count_rejected_without_spinning() {
        // count ≈ 2^32 with a 9-byte stream: more indices than input bits
        // can possibly encode. Pre-hardening this looped 4 billion times.
        let evil = craft(u32::MAX as u64, u32::MAX as u64, 0, &[]);
        assert!(decode(&evil).is_none());
        assert!(decode_with_limit(&evil, 1 << 20).is_none());
    }

    #[test]
    fn adversarial_gap_rejected_without_overflow() {
        // r = 63 with an all-ones remainder makes the second gap ≈ 2^64,
        // which used to overflow `prev + 1 + gap` (a debug-build panic).
        let mut body = vec![false]; // first index: q = 0 …
        body.extend(vec![false; 63]); // … remainder 0 → idx 0
        body.push(true); // second index: q = 1 …
        body.push(false);
        body.extend(vec![true; 63]); // … remainder 2^63 − 1
        let evil = craft(1 << 31, 2, 63, &body);
        assert!(decode(&evil).is_none());
    }

    #[test]
    fn aliased_gap_rejected_not_misdecoded() {
        // r = 63, q = 2: `q << r` wraps to 0, so pre-hardening the gap
        // aliased down to the attacker-chosen remainder and the stream
        // decoded to a *valid-looking* wrong bitmap. It must be rejected.
        let mut body = vec![true, true, false]; // q = 2
        body.extend(vec![false; 57]);
        body.extend([false, false, false, true, false, true]); // rem = 5
        let evil = craft(1 << 31, 1, 63, &body);
        assert!(decode(&evil).is_none(), "wrapped quotient decoded");
    }

    #[test]
    fn dimension_limit_bounds_allocation() {
        // A stream claiming d = 2^30 is refused before the 128 MB
        // allocation when the caller knows its model dimension.
        let evil = craft(1 << 30, 0, 0, &[]);
        assert!(decode_with_limit(&evil, 100_000).is_none());
        // The same stream with a plausible d decodes fine.
        let ok = craft(64, 0, 0, &[]);
        assert_eq!(decode_with_limit(&ok, 100_000).unwrap(), BitVec::zeros(64));
    }

    #[test]
    fn decode_with_limit_accepts_legit_streams_at_the_limit() {
        let bv = BitVec::from_indices(1000, &[0, 1, 17, 999]);
        let enc = encode(&bv);
        assert_eq!(decode_with_limit(&enc, 1000).unwrap(), bv);
        assert!(decode_with_limit(&enc, 999).is_none());
    }
}
