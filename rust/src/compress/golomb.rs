//! Golomb–Rice coding of 0-1 index arrays — the second §IV-D candidate.
//!
//! A sparse vote bitmap is a sequence of gaps between set bits; for k
//! random votes over d dimensions the gaps are ≈ geometric with mean
//! d/k, for which Golomb coding with M ≈ 0.69·d/k is the optimal prefix
//! code. We use the Rice restriction (M = 2^r) for cheap shifts — the
//! same trade-off a switch/NIC implementation would make.
//!
//! The bit I/O is **word-parallel**: the writer packs bits into a u64
//! accumulator and flushes eight bytes at a time, and the reader refills
//! a u64 accumulator and decodes unary runs with one `trailing_ones`
//! count per word instead of one branch per bit. The stream format is
//! bit-identical to the original per-bit implementation (kept in
//! [`scalar`] as the reference oracle — property tests assert equality
//! on both encode and decode, and `tests/wire_fuzz.rs` hammers the
//! refill and word-edge paths).
//!
//! `bench_compress` (E8) compares raw bitmap vs RLE vs Golomb–Rice;
//! `fediac bench-codec` measures the word-parallel speedup.

use crate::util::BitVec;

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Bit-granular writer over a u64 accumulator. Bits occupy bytes
/// little-endian-first (bit j of the stream is bit j%8 of byte j/8),
/// exactly the layout the original per-byte writer produced.
struct BitWriter {
    bytes: Vec<u8>,
    acc: u64,
    /// Bits currently buffered in `acc` (always < 64 between calls).
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), acc: 0, nbits: 0 }
    }

    /// Append the low `n` bits of `v` in LSB-first stream order.
    fn append_raw(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v & !mask(n) == 0, "append_raw got dirty high bits");
        if n == 0 {
            return;
        }
        self.acc |= v << self.nbits;
        if self.nbits + n >= 64 {
            self.bytes.extend_from_slice(&self.acc.to_le_bytes());
            let consumed = 64 - self.nbits;
            let rem = n - consumed;
            self.acc = if rem == 0 { 0 } else { v >> consumed };
            self.nbits = rem;
        } else {
            self.nbits += n;
        }
    }

    fn push_bit(&mut self, b: bool) {
        self.append_raw(b as u64, 1);
    }

    /// Append `value`'s low `n` bits MSB-first (the header/remainder
    /// order the format has always used).
    fn push_bits(&mut self, value: u64, n: u32) {
        if n == 0 {
            return;
        }
        // Reversing the low n bits turns MSB-first emission into one
        // LSB-first append.
        let rev = (value << (64 - n)).reverse_bits();
        self.append_raw(rev, n);
    }

    /// Append a unary-coded quotient: `q` one-bits then a zero.
    fn push_unary(&mut self, mut q: u64) {
        while q >= 63 {
            self.append_raw(mask(63), 63);
            q -= 63;
        }
        self.append_raw(mask(q as u32), q as u32 + 1);
    }

    fn finish(mut self) -> Vec<u8> {
        let tail_bytes = self.nbits.div_ceil(8) as usize;
        if tail_bytes > 0 {
            self.bytes.extend_from_slice(&self.acc.to_le_bytes()[..tail_bytes]);
        }
        self.bytes
    }
}

/// Bit-granular reader over a u64 accumulator refilled from the byte
/// stream (eight bytes per refill on the aligned fast path).
struct BitReader<'a> {
    bytes: &'a [u8],
    /// Next byte not yet loaded into `acc`.
    next: usize,
    acc: u64,
    /// Valid bits in `acc` (LSB-first).
    avail: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, next: 0, acc: 0, avail: 0 }
    }

    fn refill(&mut self) {
        if self.avail == 0 && self.next + 8 <= self.bytes.len() {
            self.acc =
                u64::from_le_bytes(self.bytes[self.next..self.next + 8].try_into().unwrap());
            self.avail = 64;
            self.next += 8;
            return;
        }
        while self.avail <= 56 && self.next < self.bytes.len() {
            self.acc |= (self.bytes[self.next] as u64) << self.avail;
            self.avail += 8;
            self.next += 1;
        }
    }

    /// Take `n` bits in LSB-first stream order; `None` when fewer remain.
    fn read_bits_lsb(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Some(0);
        }
        if self.avail < n {
            self.refill();
        }
        if self.avail >= n {
            let v = self.acc & mask(n);
            self.acc = if n == 64 { 0 } else { self.acc >> n };
            self.avail -= n;
            return Some(v);
        }
        // Straddling a refill boundary (or near EOF): take what is
        // buffered, refill, take the rest.
        let have = self.avail;
        let lo = self.acc;
        self.acc = 0;
        self.avail = 0;
        self.refill();
        let need = n - have;
        if self.avail < need {
            return None;
        }
        let hi = self.acc & mask(need);
        self.acc >>= need;
        self.avail -= need;
        Some(lo | (hi << have))
    }

    /// Read `n` bits MSB-first (header/remainder order); `None` at EOF.
    fn read_bits(&mut self, n: u32) -> Option<u64> {
        if n == 0 {
            return Some(0);
        }
        let v = self.read_bits_lsb(n)?;
        Some(v.reverse_bits() >> (64 - n))
    }

    /// Decode one unary run (count of consecutive one-bits up to the
    /// terminating zero) with one `trailing_ones` per buffered word.
    /// `None` at EOF mid-run or once the count exceeds `limit` — the
    /// same early bail the per-bit oracle applies one bit at a time.
    fn read_unary(&mut self, limit: u64) -> Option<u64> {
        let mut q = 0u64;
        loop {
            if self.avail == 0 {
                self.refill();
                if self.avail == 0 {
                    return None;
                }
            }
            let window = self.acc & mask(self.avail);
            let ones = (window.trailing_ones()).min(self.avail);
            q += ones as u64;
            if q > limit {
                return None;
            }
            if ones == self.avail {
                // The whole buffered word is ones: the run continues
                // across the refill boundary.
                self.acc = 0;
                self.avail = 0;
                continue;
            }
            let consume = ones + 1; // the run plus its zero terminator
            self.acc = if consume == 64 { 0 } else { self.acc >> consume };
            self.avail -= consume;
            return Some(q);
        }
    }
}

/// Rice parameter r chosen from the density: M = 2^r ≈ 0.69·d/k.
pub fn rice_param(d: usize, ones: usize) -> u32 {
    if ones == 0 || d == 0 {
        return 0;
    }
    let target = 0.69 * d as f64 / ones as f64;
    target.max(1.0).log2().round().clamp(0.0, 32.0) as u32
}

/// Encode: header (d, count, r as LEB128-ish u32s) + Rice-coded gaps.
pub fn encode(bv: &BitVec) -> Vec<u8> {
    let ones = bv.count_ones();
    let r = rice_param(bv.len(), ones);
    let mut w = BitWriter::new();
    w.push_bits(bv.len() as u64, 32);
    w.push_bits(ones as u64, 32);
    w.push_bits(r as u64, 6);
    let mut prev = 0usize;
    let mut first = true;
    for idx in bv.iter_ones() {
        let gap = if first { idx } else { idx - prev - 1 } as u64;
        first = false;
        prev = idx;
        // Rice: quotient unary + r remainder bits.
        w.push_unary(gap >> r);
        w.push_bits(gap & mask(r), r);
    }
    w.finish()
}

/// Decode; None on malformed input. The declared dimension is untrusted
/// input — callers that know the expected model dimension should prefer
/// [`decode_with_limit`], which also bounds the output allocation.
pub fn decode(bytes: &[u8]) -> Option<BitVec> {
    decode_with_limit(bytes, u32::MAX as usize)
}

/// Decode with an upper bound on the declared dimension. A mutated or
/// forged stream can claim any 32-bit `d`; without a cap that is a
/// 512 MB allocation per call. The wire client passes its own `d`, so a
/// stream that disagrees is rejected before any allocation.
pub fn decode_with_limit(bytes: &[u8], max_d: usize) -> Option<BitVec> {
    let mut rd = BitReader::new(bytes);
    let d = rd.read_bits(32)? as usize;
    let count = rd.read_bits(32)? as usize;
    let r = rd.read_bits(6)? as u32;
    if d > max_d || count > d {
        return None;
    }
    // Every coded index costs at least one bit, so `count` beyond the
    // remaining input length is malformed — and, pre-check, a forged
    // count near 2^32 would otherwise spin this loop for minutes.
    if count > bytes.len().saturating_mul(8) {
        return None;
    }
    let mut bv = BitVec::zeros(d);
    let mut prev: Option<usize> = None;
    for _ in 0..count {
        let q = rd.read_unary(d as u64)?;
        let rem = rd.read_bits(r)?;
        // `q << r` would silently discard high bits for q ≥ 2^(64−r),
        // letting a forged stream alias an astronomical gap down to an
        // attacker-chosen small one — reject before shifting.
        if r > 0 && q >= 1u64 << (64 - r) {
            return None;
        }
        let gap = (q << r) | rem;
        // Any legal gap is < d (indices are strictly increasing below d);
        // checking before the index arithmetic also keeps `prev + 1 + gap`
        // from overflowing on adversarial (q, r) combinations.
        if gap >= d as u64 {
            return None;
        }
        let idx = match prev {
            None => gap as usize,
            Some(p) => p + 1 + gap as usize,
        };
        if idx >= d {
            return None;
        }
        bv.set(idx, true);
        prev = Some(idx);
    }
    Some(bv)
}

/// Encoded size in bytes.
pub fn encoded_bytes(bv: &BitVec) -> usize {
    encode(bv).len()
}

/// The original per-bit encoder/decoder, kept as the reference oracle
/// for the word-parallel bit I/O above: property tests assert byte- and
/// bit-exact agreement, and `fediac bench-codec` measures the speedup
/// against these in the same run. Semantics (including every rejection
/// path for forged streams) are identical by construction.
pub mod scalar {
    use super::rice_param;
    use crate::util::BitVec;

    /// Per-bit writer (one byte-level read-modify-write per bit).
    pub struct BitWriter {
        bytes: Vec<u8>,
        bit: u8,
    }

    impl Default for BitWriter {
        fn default() -> Self {
            Self::new()
        }
    }

    impl BitWriter {
        /// Empty writer.
        pub fn new() -> Self {
            BitWriter { bytes: Vec::new(), bit: 0 }
        }

        /// Append one bit.
        pub fn push_bit(&mut self, b: bool) {
            if self.bit == 0 {
                self.bytes.push(0);
            }
            if b {
                *self.bytes.last_mut().unwrap() |= 1 << self.bit;
            }
            self.bit = (self.bit + 1) & 7;
        }

        /// Append `value`'s low `n` bits MSB-first.
        pub fn push_bits(&mut self, value: u64, n: u32) {
            for i in (0..n).rev() {
                self.push_bit((value >> i) & 1 == 1);
            }
        }

        /// The finished byte stream.
        pub fn finish(self) -> Vec<u8> {
            self.bytes
        }
    }

    /// Per-bit reader.
    struct BitReader<'a> {
        bytes: &'a [u8],
        pos: usize,
    }

    impl<'a> BitReader<'a> {
        fn read_bit(&mut self) -> Option<bool> {
            let byte = *self.bytes.get(self.pos >> 3)?;
            let b = (byte >> (self.pos & 7)) & 1 == 1;
            self.pos += 1;
            Some(b)
        }

        fn read_bits(&mut self, n: u32) -> Option<u64> {
            let mut v = 0u64;
            for _ in 0..n {
                v = (v << 1) | self.read_bit()? as u64;
            }
            Some(v)
        }
    }

    /// Reference [`super::encode`] (identical output bytes).
    pub fn encode(bv: &BitVec) -> Vec<u8> {
        let ones: Vec<usize> = bv.iter_ones().collect();
        let r = rice_param(bv.len(), ones.len());
        let mut w = BitWriter::new();
        w.push_bits(bv.len() as u64, 32);
        w.push_bits(ones.len() as u64, 32);
        w.push_bits(r as u64, 6);
        let mut prev = 0usize;
        for (i, &idx) in ones.iter().enumerate() {
            let gap = if i == 0 { idx } else { idx - prev - 1 } as u64;
            prev = idx;
            let q = gap >> r;
            for _ in 0..q {
                w.push_bit(true);
            }
            w.push_bit(false);
            w.push_bits(gap & ((1u64 << r) - 1).max(0), r);
        }
        w.finish()
    }

    /// Reference [`super::decode_with_limit`] (identical accept/reject
    /// behaviour and output).
    pub fn decode_with_limit(bytes: &[u8], max_d: usize) -> Option<BitVec> {
        let mut rd = BitReader { bytes, pos: 0 };
        let d = rd.read_bits(32)? as usize;
        let count = rd.read_bits(32)? as usize;
        let r = rd.read_bits(6)? as u32;
        if d > max_d || count > d {
            return None;
        }
        if count > bytes.len().saturating_mul(8) {
            return None;
        }
        let mut bv = BitVec::zeros(d);
        let mut prev: Option<usize> = None;
        for _ in 0..count {
            let mut q = 0u64;
            loop {
                match rd.read_bit()? {
                    true => q += 1,
                    false => break,
                }
                if q as usize > d {
                    return None;
                }
            }
            let rem = rd.read_bits(r)?;
            if r > 0 && q >= 1u64 << (64 - r) {
                return None;
            }
            let gap = (q << r) | rem;
            if gap >= d as u64 {
                return None;
            }
            let idx = match prev {
                None => gap as usize,
                Some(p) => p + 1 + gap as usize,
            };
            if idx >= d {
                return None;
            }
            bv.set(idx, true);
            prev = Some(idx);
        }
        Some(bv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn roundtrip_simple_patterns() {
        for pattern in [
            vec![],
            vec![0usize],
            vec![9],
            vec![0, 1, 2],
            vec![0, 5, 9],
            (0..10).collect::<Vec<_>>(),
        ] {
            let bv = BitVec::from_indices(10, &pattern);
            assert_eq!(decode(&encode(&bv)).unwrap(), bv, "{pattern:?}");
        }
    }

    #[test]
    fn roundtrip_property() {
        prop::check("golomb_roundtrip", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let density = rng.f64() * rng.f64(); // biased sparse
            let mut bv = BitVec::zeros(d);
            for i in 0..d {
                if rng.f64() < density {
                    bv.set(i, true);
                }
            }
            let dec = decode(&encode(&bv)).ok_or("decode failed")?;
            crate::prop_assert!(dec == bv, "golomb roundtrip d={d}");
            Ok(())
        });
    }

    #[test]
    fn word_encoder_matches_scalar_byte_for_byte() {
        prop::check("golomb_word_vs_scalar", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let density = rng.f64() * rng.f64();
            let mut bv = BitVec::zeros(d);
            for i in 0..d {
                if rng.f64() < density {
                    bv.set(i, true);
                }
            }
            let word = encode(&bv);
            let slow = scalar::encode(&bv);
            crate::prop_assert!(word == slow, "encoders diverged at d={d}");
            // Both decoders agree on the valid stream…
            let a = decode_with_limit(&word, d);
            let b = scalar::decode_with_limit(&word, d);
            crate::prop_assert!(a == b, "decoders diverged on valid stream d={d}");
            crate::prop_assert!(a.as_ref() == Some(&bv), "roundtrip lost bits d={d}");
            // …and on a mutated one (accept AND reject must match).
            let mut evil = word.clone();
            if !evil.is_empty() {
                let bit = rng.below(evil.len() * 8);
                evil[bit / 8] ^= 1 << (bit % 8);
            }
            let a = decode_with_limit(&evil, d);
            let b = scalar::decode_with_limit(&evil, d);
            crate::prop_assert!(a == b, "decoders diverged on mutated stream d={d}");
            Ok(())
        });
    }

    #[test]
    fn unary_runs_spanning_word_edges_match_scalar() {
        // Streams CRAFTED with an explicit r = 0 header, so each gap is
        // coded as a pure unary run of `gap` one-bits — `encode()` would
        // pick r > 0 at these densities and keep every run short. The
        // 70-bit header means every run starts mid-word, so runs of
        // 50..=200 bits cross the reader's u64 refill boundary (the
        // `ones == avail` continuation branch), which is exactly the
        // machinery under test.
        for gap in [50usize, 55, 56, 57, 58, 62, 63, 64, 65, 70, 126, 127, 128, 129, 200] {
            let d = 3 * gap + 8;
            // Index `gap` (run of `gap` ones) then index `2·gap + 1`
            // (another `gap`-long run starting unaligned).
            let mut body = vec![true; gap];
            body.push(false);
            body.extend(vec![true; gap]);
            body.push(false);
            let enc = craft(d as u64, 2, 0, &body);
            let want = BitVec::from_indices(d, &[gap, 2 * gap + 1]);
            assert_eq!(decode_with_limit(&enc, d).unwrap(), want, "gap {gap} word decode");
            assert_eq!(
                scalar::decode_with_limit(&enc, d).unwrap(),
                want,
                "gap {gap} scalar decode"
            );
            // Truncating anywhere inside the runs must fail identically
            // (EOF mid-run straddling the refill boundary).
            for cut in 9..enc.len() {
                assert_eq!(
                    decode_with_limit(&enc[..cut], d),
                    scalar::decode_with_limit(&enc[..cut], d),
                    "gap {gap} cut {cut}"
                );
            }
        }
        // The encode()-chosen r > 0 path on the same index patterns
        // (short runs + remainders) stays byte- and decode-identical too.
        for gap in [57usize, 64, 129] {
            let d = 3 * gap + 8;
            let bv = BitVec::from_indices(d, &[gap, 2 * gap + 1]);
            let enc = encode(&bv);
            assert_eq!(enc, scalar::encode(&bv), "gap {gap} encode");
            assert_eq!(decode_with_limit(&enc, d).unwrap(), bv, "gap {gap} roundtrip");
        }
    }

    #[test]
    fn sparse_votes_beat_raw_bitmap() {
        let d = 100_000;
        let k = d / 20; // the paper's 5% vote density
        let mut rng = Rng::new(11);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..k]);
        let raw = bv.payload_bytes();
        let gol = encoded_bytes(&bv);
        assert!(gol < raw, "golomb {gol} >= raw {raw}");
    }

    #[test]
    fn golomb_beats_rle_on_random_sparse() {
        // Random (geometric-gap) patterns are Golomb's sweet spot; RLE
        // wins only on long literal runs.
        use crate::compress::rle;
        let d = 50_000;
        let mut rng = Rng::new(12);
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..d / 50]);
        let gol = encoded_bytes(&bv);
        let r = rle::encoded_bytes(&bv);
        assert!(gol <= r, "golomb {gol} > rle {r} on random sparse");
    }

    #[test]
    fn rice_param_tracks_density() {
        assert!(rice_param(100_000, 50_000) < rice_param(100_000, 1_000));
        assert_eq!(rice_param(100, 0), 0);
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode(&[]).is_none());
        let enc = encode(&BitVec::from_indices(100, &[3, 50]));
        assert!(decode(&enc[..enc.len() - 1]).is_none());
    }

    /// Craft a raw stream: header (d, count, r) + explicit payload bits.
    fn craft(d: u64, count: u64, r: u32, body: &[bool]) -> Vec<u8> {
        let mut w = scalar::BitWriter::new();
        w.push_bits(d, 32);
        w.push_bits(count, 32);
        w.push_bits(r as u64, 6);
        for &b in body {
            w.push_bit(b);
        }
        w.finish()
    }

    #[test]
    fn forged_count_rejected_without_spinning() {
        // count ≈ 2^32 with a 9-byte stream: more indices than input bits
        // can possibly encode. Pre-hardening this looped 4 billion times.
        let evil = craft(u32::MAX as u64, u32::MAX as u64, 0, &[]);
        assert!(decode(&evil).is_none());
        assert!(decode_with_limit(&evil, 1 << 20).is_none());
    }

    #[test]
    fn adversarial_gap_rejected_without_overflow() {
        // r = 63 with an all-ones remainder makes the second gap ≈ 2^64,
        // which used to overflow `prev + 1 + gap` (a debug-build panic).
        let mut body = vec![false]; // first index: q = 0 …
        body.extend(vec![false; 63]); // … remainder 0 → idx 0
        body.push(true); // second index: q = 1 …
        body.push(false);
        body.extend(vec![true; 63]); // … remainder 2^63 − 1
        let evil = craft(1 << 31, 2, 63, &body);
        assert!(decode(&evil).is_none());
    }

    #[test]
    fn aliased_gap_rejected_not_misdecoded() {
        // r = 63, q = 2: `q << r` wraps to 0, so pre-hardening the gap
        // aliased down to the attacker-chosen remainder and the stream
        // decoded to a *valid-looking* wrong bitmap. It must be rejected.
        let mut body = vec![true, true, false]; // q = 2
        body.extend(vec![false; 57]);
        body.extend([false, false, false, true, false, true]); // rem = 5
        let evil = craft(1 << 31, 1, 63, &body);
        assert!(decode(&evil).is_none(), "wrapped quotient decoded");
    }

    #[test]
    fn dimension_limit_bounds_allocation() {
        // A stream claiming d = 2^30 is refused before the 128 MB
        // allocation when the caller knows its model dimension.
        let evil = craft(1 << 30, 0, 0, &[]);
        assert!(decode_with_limit(&evil, 100_000).is_none());
        // The same stream with a plausible d decodes fine.
        let ok = craft(64, 0, 0, &[]);
        assert_eq!(decode_with_limit(&ok, 100_000).unwrap(), BitVec::zeros(64));
    }

    #[test]
    fn decode_with_limit_accepts_legit_streams_at_the_limit() {
        let bv = BitVec::from_indices(1000, &[0, 1, 17, 999]);
        let enc = encode(&bv);
        assert_eq!(decode_with_limit(&enc, 1000).unwrap(), bv);
        assert!(decode_with_limit(&enc, 999).is_none());
    }

    #[test]
    fn overlong_unary_run_rejected_by_both_decoders() {
        // A run of d+2 ones never terminated by a zero: both decoders
        // must bail at the `q > d` guard, not walk the whole stream.
        let d = 256u64;
        let body = vec![true; d as usize + 2];
        let evil = craft(d, 1, 0, &body);
        assert!(decode_with_limit(&evil, 1 << 16).is_none());
        assert!(scalar::decode_with_limit(&evil, 1 << 16).is_none());
    }
}
