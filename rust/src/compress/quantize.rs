//! Unbiased stochastic integer quantisation — Eq. (1) of the paper.
//!
//! A model update U_l is amplified by f = (2^{b−1} − N)/(N·m) and rounded
//! to ⌊fU⌋ or ⌈fU⌉ with probabilities that make the result unbiased:
//! E[θ(fU)] = fU. The amplification bound guarantees the *aggregate* of N
//! clients fits in a signed (b + log₂N)-bit register without overflow.
//!
//! This is the rust mirror of the L1 Pallas kernel (same math, same
//! residual law); the PJRT backend runs the kernel artifact, the native
//! backend runs this. `tests/protocol_props.rs` cross-checks the two.

use crate::util::Rng;

/// Amplification factor f = (2^{b−1} − N)/(N·m) (§IV step 3).
pub fn scale_factor(bits_b: usize, n_clients: usize, max_abs: f32) -> f32 {
    assert!(bits_b >= 2 && bits_b <= 31, "b={bits_b} out of range");
    let numer = (1i64 << (bits_b - 1)) as f32 - n_clients as f32;
    assert!(numer > 0.0, "2^(b-1) must exceed N");
    let denom = n_clients as f32 * max_abs.max(f32::MIN_POSITIVE);
    numer / denom
}

/// Stochastically round one amplified value (Eq. 1).
#[inline]
pub fn stochastic_round(amplified: f32, rng: &mut Rng) -> i32 {
    let low = amplified.floor();
    let frac = amplified - low;
    let up = (rng.f32() < frac) as i32;
    low as i32 + up
}

/// Quantise + sparsify a full update vector against a 0/1 mask, producing
/// the integers to upload and the residual error to carry to round t+1:
/// e = (f·U − Π(Θ(f·U)))/f (Algorithm 1 line 9). `mask[i]` uses 0.0/1.0
/// exactly like the GIA the compress artifact consumes.
pub fn quantize_sparsify(
    updates: &[f32],
    mask: &[f32],
    f: f32,
    rng: &mut Rng,
) -> (Vec<i32>, Vec<f32>) {
    debug_assert_eq!(updates.len(), mask.len());
    let mut q = vec![0i32; updates.len()];
    let mut residual = vec![0f32; updates.len()];
    for i in 0..updates.len() {
        let amplified = updates[i] * f;
        if mask[i] != 0.0 {
            let v = stochastic_round(amplified, rng);
            q[i] = v;
            residual[i] = (amplified - v as f32) / f;
        } else {
            residual[i] = updates[i];
        }
    }
    (q, residual)
}

/// Dense variant (all-ones mask) used by SwitchML.
pub fn quantize_dense(updates: &[f32], f: f32, rng: &mut Rng) -> Vec<i32> {
    updates.iter().map(|&u| stochastic_round(u * f, rng)).collect()
}

/// Recover the float aggregate: w_{t+1} = w_t − Σq/(N·f) (§IV step 4).
pub fn dequantize_aggregate(agg: &[i32], n_clients: usize, f: f32) -> Vec<f32> {
    let scale = 1.0 / (n_clients as f32 * f);
    agg.iter().map(|&v| v as f32 * scale).collect()
}

/// Max |U| over a vector (the m in the scale factor).
pub fn max_abs(updates: &[f32]) -> f32 {
    updates.iter().fold(0.0f32, |m, &u| m.max(u.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn scale_factor_paper_form() {
        // b=12, N=20, m=0.5: f = (2048−20)/(20·0.5) = 202.8.
        let f = scale_factor(12, 20, 0.5);
        assert!((f - 202.8).abs() < 1e-3, "{f}");
    }

    #[test]
    fn aggregate_fits_in_register() {
        // N clients each upload ≤ f·m + 1 < 2^{b−1}/N + 1 in magnitude, so
        // the N-client sum stays far from i32 overflow for b ≤ 31.
        let n = 20;
        let b = 12;
        let m = 1.0;
        let f = scale_factor(b, n, m);
        let per_client_max = (f * m).ceil() as i64 + 1;
        assert!(n as i64 * per_client_max < (1i64 << (b as i64)));
    }

    #[test]
    fn quantization_unbiased() {
        let mut rng = Rng::new(1);
        let x = 3.37f32;
        let trials = 60_000;
        let sum: i64 = (0..trials).map(|_| stochastic_round(x, &mut rng) as i64).sum();
        let mean = sum as f64 / trials as f64;
        assert!((mean - x as f64).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn quantization_handles_negative() {
        let mut rng = Rng::new(2);
        let x = -2.25f32;
        let trials = 60_000;
        let sum: i64 = (0..trials).map(|_| stochastic_round(x, &mut rng) as i64).sum();
        let mean = sum as f64 / trials as f64;
        assert!((mean - x as f64).abs() < 0.01, "mean {mean}");
        for _ in 0..1000 {
            let v = stochastic_round(x, &mut rng);
            assert!(v == -3 || v == -2);
        }
    }

    #[test]
    fn residual_identity_property() {
        // f·U = q + f·e on masked lanes; e = U on unmasked lanes.
        prop::check("residual_identity", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            let updates = prop::gen_updates(rng, d, 0.05);
            let mask: Vec<f32> =
                (0..d).map(|_| if rng.f64() < 0.4 { 1.0 } else { 0.0 }).collect();
            let f = scale_factor(12, 20, max_abs(&updates).max(1e-6));
            let (q, e) = quantize_sparsify(&updates, &mask, f, rng);
            for i in 0..d {
                if mask[i] != 0.0 {
                    let lhs = q[i] as f64 + f as f64 * e[i] as f64;
                    let rhs = f as f64 * updates[i] as f64;
                    crate::prop_assert!(
                        (lhs - rhs).abs() <= 1e-2 * rhs.abs().max(1.0),
                        "lane {i}: {lhs} != {rhs}"
                    );
                } else {
                    crate::prop_assert!(q[i] == 0, "masked lane {i} leaked {}", q[i]);
                    crate::prop_assert!(
                        (e[i] - updates[i]).abs() < 1e-6,
                        "masked residual {i}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rounding_error_bounded_by_one() {
        prop::check("round_err_lt_1", 32, |rng| {
            let d = prop::gen_dim(rng);
            let updates = prop::gen_updates(rng, d, 0.1);
            let f = scale_factor(10, 20, max_abs(&updates).max(1e-6));
            let q = quantize_dense(&updates, f, rng);
            for i in 0..d {
                let err = (q[i] as f32 - updates[i] * f).abs();
                crate::prop_assert!(err < 1.0 + 1e-4, "lane {i} err {err}");
            }
            Ok(())
        });
    }

    #[test]
    fn dequantize_inverts_scale() {
        let agg = vec![100, -200, 0];
        let out = dequantize_aggregate(&agg, 20, 5.0);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!((out[1] + 2.0).abs() < 1e-6);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn max_abs_basics() {
        assert_eq!(max_abs(&[0.5, -2.0, 1.0]), 2.0);
        assert_eq!(max_abs(&[]), 0.0);
    }
}
