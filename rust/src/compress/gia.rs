//! Global Index Array deduction — host-side reference of §IV step 2.
//!
//! The switch's `VoteAggregator` performs this in the data plane; this
//! module is the one-shot reference used by tests (the two must agree
//! exactly) and by algorithms that need consensus statistics without a
//! switch instance (e.g. the theory explorer).

use crate::util::BitVec;

/// Aggregate client vote bitmaps and threshold with `a`:
/// GIA[l] = 1 iff at least `a` clients voted dimension l.
pub fn deduce_gia(votes: &[BitVec], threshold_a: usize) -> BitVec {
    assert!(!votes.is_empty());
    let d = votes[0].len();
    let mut counts = vec![0u16; d];
    for v in votes {
        assert_eq!(v.len(), d, "vote arrays must share dimension");
        for i in v.iter_ones() {
            counts[i] += 1;
        }
    }
    let mut gia = BitVec::zeros(d);
    for (i, &c) in counts.iter().enumerate() {
        if c as usize >= threshold_a {
            gia.set(i, true);
        }
    }
    gia
}

/// Vote histogram (how many clients voted each dimension).
pub fn vote_histogram(votes: &[BitVec]) -> Vec<u16> {
    let d = votes[0].len();
    let mut counts = vec![0u16; d];
    for v in votes {
        for i in v.iter_ones() {
            counts[i] += 1;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn motivation_example() {
        // §III-B: 11100 and 01110 with a=2 ⇒ 01100.
        let votes = vec![
            BitVec::from_indices(5, &[0, 1, 2]),
            BitVec::from_indices(5, &[1, 2, 3]),
        ];
        let gia = deduce_gia(&votes, 2);
        assert_eq!(gia.iter_ones().collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn threshold_one_is_union() {
        let votes = vec![
            BitVec::from_indices(8, &[0, 1]),
            BitVec::from_indices(8, &[6]),
        ];
        let gia = deduce_gia(&votes, 1);
        assert_eq!(gia.iter_ones().collect::<Vec<_>>(), vec![0, 1, 6]);
    }

    #[test]
    fn threshold_n_is_intersection() {
        let votes = vec![
            BitVec::from_indices(8, &[0, 1, 5]),
            BitVec::from_indices(8, &[1, 5, 7]),
            BitVec::from_indices(8, &[1, 2, 5]),
        ];
        let gia = deduce_gia(&votes, 3);
        assert_eq!(gia.iter_ones().collect::<Vec<_>>(), vec![1, 5]);
    }

    #[test]
    fn gia_monotone_in_threshold() {
        // Raising a can only shrink the GIA (the property behind the
        // paper's "larger a ⇒ higher compression rate" remark).
        prop::check("gia_monotone", 32, |rng| {
            let d = 128;
            let n = 2 + rng.below(18);
            let votes: Vec<BitVec> = (0..n)
                .map(|_| {
                    let k = rng.below(d);
                    let mut idx: Vec<usize> = (0..d).collect();
                    let mut r2 = Rng::new(rng.next_u64());
                    r2.shuffle(&mut idx);
                    BitVec::from_indices(d, &idx[..k])
                })
                .collect();
            let mut prev = deduce_gia(&votes, 1).count_ones();
            for a in 2..=n {
                let cur = deduce_gia(&votes, a).count_ones();
                crate::prop_assert!(cur <= prev, "a={a}: {cur} > {prev}");
                prev = cur;
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_counts() {
        let votes = vec![
            BitVec::from_indices(4, &[0, 2]),
            BitVec::from_indices(4, &[0, 3]),
        ];
        assert_eq!(vote_histogram(&votes), vec![2, 0, 1, 1]);
    }
}
