//! FediAC: voting-based consensus model compression for in-network FL.
//!
//! Reproduction of Su et al., "Expediting In-Network Federated Learning by
//! Voting-Based Consensus Model Compression" (2024). See DESIGN.md for the
//! architecture, README.md for usage, and PROTOCOL.md for the normative
//! wire-protocol specification.

// Doc rot fails CI: `cargo doc --no-deps` runs with `-D warnings`, so
// every public item (fields and stat counters included) must say what
// it is for.
#![warn(missing_docs)]

pub mod algorithms;
pub mod bench_codec;
pub mod bench_wire;
pub mod cli;
pub mod client;
pub mod configx;
pub mod compress;
pub mod data;
pub mod experiments;
pub mod fl;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod switch;
pub mod telemetry;
pub mod theory;
pub mod util;
pub mod wire;
