//! FediAC: voting-based consensus model compression for in-network FL.
//!
//! Reproduction of Su et al., "Expediting In-Network Federated Learning by
//! Voting-Based Consensus Model Compression" (2024). See DESIGN.md for the
//! architecture, README.md for usage, and PROTOCOL.md for the normative
//! wire-protocol specification.

// Doc rot fails CI: `cargo doc --no-deps` runs with `-D warnings`, so
// every public item (fields and stat counters included) must say what
// it is for.
#![warn(missing_docs)]

pub mod algorithms;
pub mod bench_codec;
pub mod bench_wire;
pub mod cli;
pub mod client;
pub mod configx;
pub mod compress;
pub mod data;
pub mod experiments;
pub mod fl;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod soak;
pub mod switch;
pub mod telemetry;
pub mod theory;
pub mod trendgate;
pub mod util;
pub mod wire;

#[cfg(test)]
mod test_registration {
    //! Guard against silently unregistered integration tests: the crate
    //! sets `autotests = false` (every suite is an explicit `[[test]]`
    //! target), so a file landing in `tests/` without a manifest entry
    //! would never compile in CI — exactly how `tests/client_machine.rs`
    //! shipped dark for a full release cycle.

    use std::collections::BTreeSet;
    use std::path::Path;

    #[test]
    fn every_tests_file_is_a_cargo_test_target_and_vice_versa() {
        let manifest_dir = env!("CARGO_MANIFEST_DIR");
        let manifest =
            std::fs::read_to_string(Path::new(manifest_dir).join("Cargo.toml")).unwrap();
        let registered: BTreeSet<String> = manifest
            .lines()
            .filter_map(|l| l.trim().strip_prefix("path = "))
            .filter_map(|v| v.trim().strip_prefix('"')?.strip_suffix('"'))
            .filter_map(|p| p.strip_prefix("tests/"))
            .map(|p| p.to_string())
            .collect();
        let on_disk: BTreeSet<String> = std::fs::read_dir(Path::new(manifest_dir).join("tests"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.ends_with(".rs"))
            .collect();
        let unregistered: Vec<&String> = on_disk.difference(&registered).collect();
        assert!(
            unregistered.is_empty(),
            "tests/ files missing a [[test]] entry in Cargo.toml (they never run): \
             {unregistered:?}"
        );
        let missing: Vec<&String> = registered.difference(&on_disk).collect();
        assert!(
            missing.is_empty(),
            "Cargo.toml [[test]] entries with no file under tests/: {missing:?}"
        );
    }
}
