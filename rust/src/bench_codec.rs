//! `fediac bench-codec`: microbenchmarks of the data-plane hot-path
//! kernels, each measured against its scalar reference oracle **in the
//! same run** — the codec-level perf baseline the wire benches build on.
//!
//! Four kernel pairs plus the frame emitter:
//!
//! * `golomb_encode` / `golomb_decode` — word-parallel bit I/O
//!   ([`crate::compress::golomb`]) vs the per-bit `scalar` oracle;
//! * `vote_absorb` — [`crate::switch::alu::add_vote_bits`] (set-bit
//!   iteration over u64 words) vs the per-bit walk;
//! * `lane_add` — [`crate::switch::alu::add_i32_sat`] (branchless
//!   autovectorizable saturation) vs the branching loop;
//! * `threshold` — [`crate::switch::alu::threshold_votes`] word packing
//!   vs per-bit read-modify-write;
//! * `frame_encode` — pooled [`crate::wire::FrameScratch`] emission vs a
//!   fresh allocation per frame, asserting `pool_misses == 0` once warm.
//!
//! Emits `BENCH_CODEC.json` (CI runs `--smoke` so the perf trajectory
//! accumulates next to `BENCH_WIRE.json`).

use std::hint::black_box;
use std::time::Instant;

use anyhow::Result;

use crate::compress::golomb;
use crate::switch::alu;
use crate::util::{BitVec, Rng};
use crate::wire::{encode_frame, FrameScratch, Header, WireKind};

/// Workload shape for one bench-codec run.
#[derive(Debug, Clone)]
pub struct BenchCodecOptions {
    /// Model dimension d for bitmaps / counters / lane vectors.
    pub d: usize,
    /// Vote density (the paper's phase-1 k/d; 0.05 default).
    pub density: f64,
    /// Timed iterations per kernel (after warm-up).
    pub iters: usize,
    /// Payload bytes per frame in the frame-encode bench.
    pub payload_budget: usize,
    /// Frames emitted per iteration of the frame-encode bench.
    pub frames_per_iter: usize,
    /// Seed for the synthetic bitmaps and lane vectors.
    pub seed: u64,
}

impl Default for BenchCodecOptions {
    fn default() -> Self {
        BenchCodecOptions {
            d: 1 << 20,
            density: 0.05,
            iters: 40,
            payload_budget: 1408,
            frames_per_iter: 64,
            seed: 7,
        }
    }
}

impl BenchCodecOptions {
    /// Tiny CI-friendly workload (`fediac bench-codec --smoke`).
    pub fn smoke() -> Self {
        BenchCodecOptions { d: 1 << 16, iters: 8, ..BenchCodecOptions::default() }
    }
}

/// One kernel's fast-vs-oracle measurement.
#[derive(Debug, Clone)]
pub struct KernelReport {
    /// Kernel name (`golomb_decode`, `vote_absorb`, …).
    pub name: &'static str,
    /// Logical elements processed per iteration (bits or lanes).
    pub elems_per_iter: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Wall seconds for the word-parallel kernel.
    pub fast_s: f64,
    /// Wall seconds for the scalar oracle over the identical input.
    pub scalar_s: f64,
    /// `scalar_s / fast_s` — the headline speedup.
    pub speedup: f64,
    /// Word-parallel throughput in mega-elements per second.
    pub fast_melems_s: f64,
}

/// The frame-emission measurement (pool vs per-frame allocation).
#[derive(Debug, Clone)]
pub struct FrameReport {
    /// Frames emitted per timed pass.
    pub frames: usize,
    /// Wall seconds for pooled emission ([`FrameScratch`]).
    pub pooled_s: f64,
    /// Wall seconds for fresh-allocation emission (`encode_frame`).
    pub alloc_s: f64,
    /// `alloc_s / pooled_s`.
    pub speedup: f64,
    /// Pool misses during the timed (steady-state) passes — the
    /// allocation-free claim is exactly `== 0`.
    pub steady_misses: u64,
    /// Pool hits during the timed passes.
    pub steady_hits: u64,
}

/// A full bench-codec run.
#[derive(Debug, Clone)]
pub struct BenchCodecReport {
    /// The workload that produced these numbers.
    pub opts: BenchCodecOptions,
    /// One entry per kernel pair.
    pub kernels: Vec<KernelReport>,
    /// The frame-emission measurement.
    pub frame: FrameReport,
}

impl BenchCodecReport {
    /// Serialise to the `BENCH_CODEC.json` schema (hand-rolled — the
    /// crate builds offline without a JSON serializer).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"config\": {{\"d\": {}, \"density\": {}, \"iters\": {}, \
             \"payload_budget\": {}, \"frames_per_iter\": {}, \"seed\": {}}},\n",
            self.opts.d,
            self.opts.density,
            self.opts.iters,
            self.opts.payload_budget,
            self.opts.frames_per_iter,
            self.opts.seed
        ));
        out.push_str("  \"kernels\": [\n");
        for (i, k) in self.kernels.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"elems_per_iter\": {}, \"iters\": {}, \
                 \"fast_s\": {:.6}, \"scalar_s\": {:.6}, \"speedup\": {:.2}, \
                 \"fast_melems_s\": {:.1}}}{}\n",
                k.name,
                k.elems_per_iter,
                k.iters,
                k.fast_s,
                k.scalar_s,
                k.speedup,
                k.fast_melems_s,
                if i + 1 < self.kernels.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n");
        out.push_str(&format!(
            "  \"frame_encode\": {{\"frames\": {}, \"pooled_s\": {:.6}, \"alloc_s\": {:.6}, \
             \"speedup\": {:.2}, \"steady_misses\": {}, \"steady_hits\": {}}}\n",
            self.frame.frames,
            self.frame.pooled_s,
            self.frame.alloc_s,
            self.frame.speedup,
            self.frame.steady_misses,
            self.frame.steady_hits
        ));
        out.push_str("}\n");
        out
    }

    /// Human-readable TSV block (the shape the other `bench_*` targets
    /// print).
    pub fn render(&self) -> String {
        let mut out = format!(
            "# bench_codec: d={} density={} iters={} payload={} seed={}\n\
             kernel\telems/iter\tword_s\tscalar_s\tspeedup\tword_Melems/s\n",
            self.opts.d, self.opts.density, self.opts.iters, self.opts.payload_budget,
            self.opts.seed
        );
        for k in &self.kernels {
            out.push_str(&format!(
                "{}\t{}\t{:.4}\t{:.4}\t{:.2}x\t{:.1}\n",
                k.name, k.elems_per_iter, k.fast_s, k.scalar_s, k.speedup, k.fast_melems_s
            ));
        }
        out.push_str(&format!(
            "frame_encode\t{} frames\t{:.4}\t{:.4}\t{:.2}x\tsteady_misses={}\n",
            self.frame.frames,
            self.frame.pooled_s,
            self.frame.alloc_s,
            self.frame.speedup,
            self.frame.steady_misses
        ));
        out
    }
}

/// Time `f` over `iters` iterations after `warmup` untimed ones.
fn time_loop(iters: usize, warmup: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_secs_f64().max(f64::EPSILON)
}

fn paper_bitmap(rng: &mut Rng, d: usize, density: f64) -> BitVec {
    let mut bv = BitVec::zeros(d);
    for i in 0..d {
        if rng.f64() < density {
            bv.set(i, true);
        }
    }
    bv
}

fn report(
    name: &'static str,
    elems_per_iter: usize,
    iters: usize,
    fast_s: f64,
    scalar_s: f64,
) -> KernelReport {
    KernelReport {
        name,
        elems_per_iter,
        iters,
        fast_s,
        scalar_s,
        speedup: scalar_s / fast_s,
        fast_melems_s: (elems_per_iter as f64 * iters as f64) / fast_s / 1e6,
    }
}

/// Run the whole suite and collect the report.
pub fn run(opts: &BenchCodecOptions) -> Result<BenchCodecReport> {
    anyhow::ensure!(opts.d > 0 && opts.iters > 0, "d and iters must be > 0");
    let mut rng = Rng::new(opts.seed);
    let d = opts.d;
    let iters = opts.iters;
    let warmup = (iters / 4).max(1);
    let bv = paper_bitmap(&mut rng, d, opts.density);
    let mut kernels = Vec::new();

    // --- golomb encode ---------------------------------------------------
    let fast_s = time_loop(iters, warmup, || {
        black_box(golomb::encode(black_box(&bv)));
    });
    let scalar_s = time_loop(iters, warmup, || {
        black_box(golomb::scalar::encode(black_box(&bv)));
    });
    kernels.push(report("golomb_encode", d, iters, fast_s, scalar_s));

    // --- golomb decode ---------------------------------------------------
    let encoded = golomb::encode(&bv);
    debug_assert_eq!(encoded, golomb::scalar::encode(&bv));
    let fast_s = time_loop(iters, warmup, || {
        black_box(golomb::decode_with_limit(black_box(&encoded), d)).unwrap();
    });
    let scalar_s = time_loop(iters, warmup, || {
        black_box(golomb::scalar::decode_with_limit(black_box(&encoded), d)).unwrap();
    });
    kernels.push(report("golomb_decode", d, iters, fast_s, scalar_s));

    // --- vote absorb -----------------------------------------------------
    // Saturating counters, so repeated absorption needs no reset; both
    // sides chew the identical payload the same number of times.
    let payload = bv.to_bytes();
    let mut counters_fast = vec![0u16; d];
    let mut counters_slow = vec![0u16; d];
    let fast_s = time_loop(iters, warmup, || {
        alu::add_vote_bits(black_box(&mut counters_fast), black_box(&payload));
    });
    let scalar_s = time_loop(iters, warmup, || {
        alu::scalar::add_vote_bits(black_box(&mut counters_slow), black_box(&payload));
    });
    anyhow::ensure!(counters_fast == counters_slow, "vote kernels diverged in-bench");
    kernels.push(report("vote_absorb", d, iters, fast_s, scalar_s));

    // --- threshold -------------------------------------------------------
    let mut gia_fast = vec![0u8; d.div_ceil(8)];
    let mut gia_slow = vec![0u8; d.div_ceil(8)];
    let fast_s = time_loop(iters, warmup, || {
        alu::threshold_votes(black_box(&counters_fast), 3, black_box(&mut gia_fast));
    });
    let scalar_s = time_loop(iters, warmup, || {
        alu::scalar::threshold_votes(black_box(&counters_slow), 3, black_box(&mut gia_slow));
    });
    anyhow::ensure!(gia_fast == gia_slow, "threshold kernels diverged in-bench");
    kernels.push(report("threshold", d, iters, fast_s, scalar_s));

    // --- lane add --------------------------------------------------------
    let lanes: Vec<i32> = (0..d).map(|_| (rng.next_u32() as i32) >> 12).collect();
    let mut acc_fast = vec![0i32; d];
    let mut acc_slow = vec![0i32; d];
    let fast_s = time_loop(iters, warmup, || {
        black_box(alu::add_i32_sat(black_box(&mut acc_fast), black_box(&lanes)));
    });
    let scalar_s = time_loop(iters, warmup, || {
        black_box(alu::scalar::add_i32_sat(black_box(&mut acc_slow), black_box(&lanes)));
    });
    anyhow::ensure!(acc_fast == acc_slow, "lane kernels diverged in-bench");
    kernels.push(report("lane_add", d, iters, fast_s, scalar_s));

    // --- frame encode: pooled vs per-frame allocation --------------------
    let payload: Vec<u8> = (0..opts.payload_budget).map(|_| rng.next_u32() as u8).collect();
    let header = Header {
        kind: WireKind::Update,
        client: 1,
        job: 7,
        round: 1,
        block: 0,
        n_blocks: 1,
        elems: (opts.payload_budget / 4) as u32,
        aux: 0,
    };
    fn emit_pooled(
        pool: &mut FrameScratch,
        burst: &mut Vec<Vec<u8>>,
        frames: usize,
        header: &Header,
        payload: &[u8],
    ) {
        for _ in 0..frames {
            burst.push(pool.encode(header, payload));
        }
        for b in burst.drain(..) {
            pool.give(b);
        }
    }
    let frames = opts.frames_per_iter;
    let mut pool = FrameScratch::new();
    let mut burst: Vec<Vec<u8>> = Vec::with_capacity(frames);
    // Warm the pool, then zero the counters so the timed passes measure
    // pure steady state.
    for _ in 0..warmup {
        emit_pooled(&mut pool, &mut burst, frames, &header, &payload);
    }
    pool.drain_counters();
    let start = Instant::now();
    for _ in 0..iters {
        emit_pooled(&mut pool, &mut burst, frames, &header, &payload);
    }
    let pooled_s = start.elapsed().as_secs_f64().max(f64::EPSILON);
    let (steady_hits, steady_misses) = pool.drain_counters();
    let alloc_s = time_loop(iters, warmup, || {
        for _ in 0..frames {
            black_box(encode_frame(&header, &payload));
        }
    });
    let frame = FrameReport {
        frames: frames * iters,
        pooled_s,
        alloc_s,
        speedup: alloc_s / pooled_s,
        steady_misses,
        steady_hits,
    };

    Ok(BenchCodecReport { opts: opts.clone(), kernels, frame })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_produces_consistent_report() {
        let mut opts = BenchCodecOptions::smoke();
        opts.d = 1 << 12;
        opts.iters = 2;
        let rep = run(&opts).unwrap();
        assert_eq!(rep.kernels.len(), 5);
        for k in &rep.kernels {
            assert!(k.fast_s > 0.0 && k.scalar_s > 0.0, "{}", k.name);
            assert!(k.speedup.is_finite());
        }
        assert_eq!(
            rep.frame.steady_misses, 0,
            "steady-state frame emission allocated"
        );
        assert!(rep.frame.steady_hits > 0);
        let json = rep.to_json();
        assert!(json.contains("\"golomb_decode\""));
        assert!(json.contains("\"steady_misses\": 0"));
        assert!(rep.render().contains("vote_absorb"));
    }
}
