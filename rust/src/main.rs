//! `fediac` — leader binary: run paper experiments and single training
//! jobs from the command line.
//!
//! ```text
//! fediac train  [--dataset cifar10] [--partition iid|dirichlet|natural]
//!               [--algorithm fediac] [--rounds 40] [--clients 20]
//!               [--ps high|low] [--backend native|pjrt] [--config file.toml]
//! fediac fig2   [--dataset …] [--ps …] [--scale quick|standard] …
//! fediac table  [--ps high|low] [--scale …]
//! fediac fig3   [--ps …]
//! fediac fig4   [--partition iid|dirichlet]
//! fediac theory [--d 100000] [--clients 20] [--a 3] [--b 12]
//! fediac serve  [--preset datacenter|edge|adversarial|paper|FILE.toml]
//!               [--bind 0.0.0.0:7177] [--io threaded|reactor|fleet]
//!               [--cores N] [--ps high|low] [--memory BYTES]
//!               [--host-bytes BYTES] [--down-drop 0.0] [--down-dup 0.0]
//!               [--down-reorder 0.0] [--down-corrupt 0.0] [--chaos-seed 0]
//!               [--stats-every 10] [--metrics-interval 0] [--trace-dump PATH]
//! fediac shard-serve [--preset NAME] [--bind-base 0.0.0.0:7177] [--shards 2]
//!               [--io threaded|reactor|fleet] [--cores N]
//!               [--ps high|low] [--memory BYTES]
//!               [--host-bytes BYTES] [--down-*…] [--chaos-seed 0]
//!               [--stats-every 10] [--metrics-interval 0] [--trace-dump PATH]
//! fediac bench-wire [--smoke] [--jobs 4] [--rounds 3] [--clients 2]
//!               [--d 4096] [--payload 1408]
//!               [--io both|threaded|reactor|fleet] [--cores N]
//!               [--ps high|low] [--memory BYTES] [--seed 7]
//!               [--shards N] [--swarm] [--swarm-sockets 8]
//!               [--down-drop 0.0] [--down-dup 0.0] [--down-reorder 0.0]
//!               [--down-corrupt 0.0] [--chaos-seed SEED]
//!               [--out BENCH_WIRE.json]
//! fediac bench-codec [--smoke] [--d 1048576] [--iters 40] [--density 0.05]
//!               [--payload 1408] [--seed 7] [--out BENCH_CODEC.json]
//! fediac client [--server host:port | --shards host:p0,host:p1,…]
//!               [--job 1] [--client-id 0]
//!               [--clients 4] [--d 4096] [--rounds 2] [--a 3] [--b 12]
//!               [--k-frac 0.05] [--seed 7] [--loss 0.0] [--quorum 0]
//!               [--chaos-drop 0.0] [--chaos-dup 0.0] [--chaos-reorder 0.0]
//!               [--chaos-corrupt 0.0] [--chaos-seed 1]
//! fediac swarm  [--preset NAME] [--server host:port] [--clients 10000]
//!               [--clients-per-job 64]
//!               [--sockets 8] [--rounds 1] [--d 1024] [--a 3] [--b 12]
//!               [--k-frac 0.05] [--payload 1408] [--timeout-ms 200]
//!               [--max-retries 50] [--seed 7] [--quorum 0]
//!               [--chaos-drop 0.0] [--chaos-dup 0.0] [--chaos-reorder 0.0]
//!               [--chaos-corrupt 0.0] [--chaos-seed SEED] [--json PATH]
//! fediac soak   [--episodes 8] [--duration 300] [--seed 7]
//!               [--episode-seed SEED] [--presets a,b,…] [--out SOAK.json]
//! fediac trend-gate [--baseline bench_baseline.json]
//!               [--current BENCH_WIRE.json] [--baseline-codec PATH]
//!               [--current-codec PATH] [--tol-throughput 0.5]
//!               [--tol-latency 4.0]
//! fediac chaos  [--listen 127.0.0.1:7178] [--upstream 127.0.0.1:7177]
//!               [--seed 1] [--up-drop 0.0] [--up-dup 0.0] [--up-reorder 0.0]
//!               [--up-corrupt 0.0] [--up-depth 4] [--up-hold-ms 40]
//!               [--down-*…] [--stats-every 10]
//! ```
//!
//! All experiment output goes to stdout as TSV blocks; CSVs land in
//! `results/`.

use anyhow::Result;

use fediac::cli::Args;
use fediac::configx::{
    AlgorithmKind, BackendKind, DatasetKind, ExperimentConfig, Partition, PsProfile,
};
use fediac::experiments::{self, fig2, fig3, fig4, tables, RunOptions, Scale};
use fediac::theory::{prop1_evaluate, PowerLaw, Prop1Params};

fn scale_from(args: &Args) -> Result<Scale> {
    let mut scale = match args.get_str("scale", "standard").as_str() {
        "quick" => Scale::quick(),
        "standard" => Scale::standard(),
        other => anyhow::bail!("unknown --scale '{other}' (quick|standard)"),
    };
    scale.rounds = args.get_usize("rounds", scale.rounds)?;
    scale.num_clients = args.get_usize("clients", scale.num_clients)?;
    scale.samples_per_client =
        args.get_usize("samples", scale.samples_per_client)?;
    scale.eval_every = args.get_usize("eval-every", scale.eval_every)?;
    scale.seed = args.get_u64("seed", scale.seed)?;
    scale.net_scale = args.get_f64("net-scale", scale.net_scale)?;
    if let Some(limit) = args.get_opt_str("time-limit") {
        scale.sim_time_limit_s = Some(limit.parse()?);
    }
    if let Some(b) = args.get_opt_str("backend") {
        scale.backend = BackendKind::parse(&b)
            .ok_or_else(|| anyhow::anyhow!("unknown --backend '{b}'"))?;
    }
    Ok(scale)
}

fn opts_from(args: &Args) -> Result<RunOptions> {
    Ok(RunOptions {
        eval_every: args.get_usize("eval-every", 2)?,
        verbose: !args.get_flag("quiet"),
        artifact_dir: args.get_str("artifact-dir", "artifacts"),
        native_hidden: args.get_usize("hidden", 64)?,
        native_batch: args.get_usize("batch", 16)?,
    })
}

fn dataset_from(args: &Args, default: DatasetKind) -> Result<DatasetKind> {
    let name = args.get_str("dataset", default.name());
    DatasetKind::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown --dataset '{name}'"))
}

fn partition_from(args: &Args, default: &str) -> Result<Partition> {
    Ok(match args.get_str("partition", default).as_str() {
        "iid" => Partition::Iid,
        "natural" => Partition::Natural,
        "dirichlet" => Partition::Dirichlet(args.get_f64("beta", 0.5)?),
        other => anyhow::bail!("unknown --partition '{other}'"),
    })
}

fn ps_from(args: &Args) -> Result<PsProfile> {
    let name = args.get_str("ps", "high");
    PsProfile::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown --ps '{name}'"))
}

fn save(path: &str, contents: &str) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)?;
    fediac::info!("wrote {path}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let dataset = dataset_from(args, DatasetKind::Tiny)?;
    let default_part = if dataset == DatasetKind::SynthFemnist { "natural" } else { "iid" };
    let partition = partition_from(args, default_part)?;
    let mut cfg = ExperimentConfig::preset(dataset, partition);
    scale.apply(&mut cfg);
    cfg.ps = ps_from(args)?;
    let alg_name = args.get_str("algorithm", "fediac");
    cfg.algorithm = AlgorithmKind::parse(&alg_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --algorithm '{alg_name}'"))?;
    if let Some(path) = args.get_opt_str("config") {
        cfg.apply_file(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    if let Some(a) = args.get_opt_str("a") {
        cfg.fediac.threshold_a = a.parse()?;
    }
    if let Some(b) = args.get_opt_str("b") {
        cfg.fediac.bits_b = Some(b.parse()?);
    }
    cfg.fediac.rle_phase1 = args.get_flag("rle");
    cfg.num_switches = args.get_usize("switches", cfg.num_switches)?;
    cfg.lr.base = args.get_f64("lr", cfg.lr.base)?;
    cfg.loss_rate = args.get_f64("loss", cfg.loss_rate)?;
    let opts = opts_from(args)?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let rec = experiments::run(&cfg, &opts)?;
    println!("{}", rec.to_csv());
    let best = rec.best_accuracy().unwrap_or(0.0);
    fediac::info!(
        "{}: best_acc={:.4} total_traffic={:.2} MB sim_time={:.1} s",
        cfg.label(),
        best,
        rec.total_traffic().total_mb(),
        rec.final_time()
    );
    rec.write_csv(&format!("results/train_{}.csv", cfg.label()))?;
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let opts = opts_from(args)?;
    let only_dataset = args.get_opt_str("dataset");
    let only_ps = args.get_opt_str("ps");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let panels: Vec<(DatasetKind, Partition)> = vec![
        (DatasetKind::SynthCifar10, Partition::Iid),
        (DatasetKind::SynthCifar10, Partition::Dirichlet(0.5)),
        (DatasetKind::SynthCifar100, Partition::Iid),
        (DatasetKind::SynthCifar100, Partition::Dirichlet(0.5)),
        (DatasetKind::SynthFemnist, Partition::Natural),
    ];
    for ps in [PsProfile::high(), PsProfile::low()] {
        if let Some(ref p) = only_ps {
            if *p != ps.name {
                continue;
            }
        }
        for (dataset, partition) in &panels {
            if let Some(ref d) = only_dataset {
                if d != dataset.name() {
                    continue;
                }
            }
            let panel = fig2::run_panel(*dataset, *partition, ps.clone(), &scale, &opts)?;
            let tsv = fig2::render_panel(&panel);
            println!("{tsv}");
            save(
                &format!(
                    "results/fig2_{}_{}_{}.tsv",
                    dataset.name(),
                    partition.name().replace(['(', ')'], "_"),
                    ps.name
                ),
                &tsv,
            )?;
        }
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let opts = opts_from(args)?;
    let ps = ps_from(args)?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut rows = Vec::new();
    for (dataset, partition, target) in tables::scenarios() {
        rows.push(tables::run_row(dataset, partition, target, ps.clone(), &scale, &opts)?);
    }
    let txt = tables::render(&rows, &ps.name);
    println!("{txt}");
    save(&format!("results/table_{}.tsv", ps.name), &txt)?;
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let opts = opts_from(args)?;
    let only_ps = args.get_opt_str("ps");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;
    for ps in [PsProfile::high(), PsProfile::low()] {
        if let Some(ref p) = only_ps {
            if *p != ps.name {
                continue;
            }
        }
        let res = fig3::run_sweep(ps.clone(), &scale, &opts, &fig3::BETAS)?;
        let txt = fig3::render(&res, &ps.name);
        println!("{txt}");
        save(&format!("results/fig3_{}.tsv", ps.name), &txt)?;
    }
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let opts = opts_from(args)?;
    let partition = partition_from(args, "iid")?;
    let clients: Vec<usize> = args
        .get_str("client-grid", "20,30,40,50")
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;
    let res = fig4::run_sweep(partition, &clients, &scale, &opts)?;
    let label = partition.name();
    let txt = fig4::render(&res, &label);
    println!("{txt}");
    save(&format!("results/fig4_{}.tsv", label.replace(['(', ')'], "_")), &txt)?;
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let d = args.get_usize("d", 100_000)?;
    let n = args.get_usize("clients", 20)?;
    let k = args.get_usize("k", d / 20)?;
    let a = args.get_usize("a", 3)?;
    let b = args.get_usize("b", 12)?;
    let phi = args.get_f64("phi", 0.1)?;
    let alpha = args.get_f64("alpha", -0.7)?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;
    let out = prop1_evaluate(&Prop1Params {
        d,
        n_clients: n,
        k,
        threshold_a: a,
        law: PowerLaw { phi, alpha },
        bits_b: b,
    });
    println!(
        "prop1: d={d} N={n} k={k} a={a} b={b} phi={phi} alpha={alpha}\n\
         gamma={:.6}  E[k_S]={:.1} ({:.2}% of d)  f={:.2}\n\
         min_bits(cor.1)={}",
        out.gamma,
        out.expected_uploads,
        100.0 * out.expected_uploads / d as f64,
        out.f,
        fediac::theory::min_bits(d, n, k, a, &PowerLaw { phi, alpha }),
    );
    Ok(())
}

/// Read one chaos direction's knobs from `--<prefix>-*` options on top
/// of `base` defaults (all-zero probabilities for plain CLI use, or a
/// deployment preset's knobs so flags override the preset per field).
fn chaos_direction_over(
    args: &Args,
    prefix: &str,
    base: fediac::net::ChaosDirection,
) -> Result<fediac::net::ChaosDirection> {
    Ok(fediac::net::ChaosDirection {
        drop: args.get_f64(&format!("{prefix}-drop"), base.drop)?,
        duplicate: args.get_f64(&format!("{prefix}-dup"), base.duplicate)?,
        reorder: args.get_f64(&format!("{prefix}-reorder"), base.reorder)?,
        corrupt: args.get_f64(&format!("{prefix}-corrupt"), base.corrupt)?,
        reorder_depth: args.get_usize(&format!("{prefix}-depth"), base.reorder_depth)?,
        max_hold: std::time::Duration::from_millis(
            args.get_u64(&format!("{prefix}-hold-ms"), base.max_hold.as_millis() as u64)?,
        ),
    })
}

/// Read one chaos direction's knobs from `--<prefix>-*` options
/// (defaults: no faults).
fn chaos_direction_from(args: &Args, prefix: &str) -> Result<fediac::net::ChaosDirection> {
    chaos_direction_over(args, prefix, fediac::net::ChaosDirection::default())
}

/// Resolve `--preset NAME` (builtin name or TOML path) when given.
fn preset_from(args: &Args) -> Result<Option<fediac::configx::DeployPreset>> {
    args.get_opt_str("preset")
        .map(|name| {
            fediac::configx::load_preset(&name)
                .map_err(|e| anyhow::anyhow!("--preset {name}: {e}"))
        })
        .transpose()
}

/// `--trace-dump` target: the daemon-attached flight recorder plus the
/// path its ring is rewritten to on every stats tick.
type TraceDump = Option<(std::sync::Arc<fediac::telemetry::FlightRecorder>, String)>;

/// Telemetry/cadence knobs parsed alongside [`fediac::server::ServeOptions`]:
/// the human-readable stats cadence, the machine-readable JSON-lines
/// metrics cadence (0 = off), and the flight-recorder dump target
/// (recorder + path) when `--trace-dump` is given.
struct ServeTelemetry {
    stats_every: u64,
    metrics_interval: u64,
    trace_dump: TraceDump,
}

/// Parse the serve-family options shared by `serve` and `shard-serve`
/// (profile, register memory, host-byte limits, downlink chaos, seed)
/// plus the stats/metrics cadences and the flight-recorder dump — one
/// list, so the two subcommands cannot grow divergent CLI surfaces.
///
/// `--preset` (when given) supplies the defaults for every knob here;
/// explicit flags override it field by field. The resolved preset is
/// returned so callers can consume its deployment shape too (e.g.
/// `shard-serve` takes its shard count).
fn serve_options_from(
    args: &Args,
    bind: String,
) -> Result<(fediac::server::ServeOptions, ServeTelemetry, Option<fediac::configx::DeployPreset>)>
{
    let preset = preset_from(args)?;
    let mut profile = match args.get_opt_str("ps") {
        Some(name) => PsProfile::parse(&name)
            .ok_or_else(|| anyhow::anyhow!("unknown --ps '{name}'"))?,
        None => preset.as_ref().map(|p| p.ps_profile()).unwrap_or_else(PsProfile::high),
    };
    profile.memory_bytes = args.get_usize("memory", profile.memory_bytes)?;
    let stats_every = args.get_u64("stats-every", 10)?;
    let metrics_interval = args.get_u64("metrics-interval", 0)?;
    // --trace-dump PATH: attach a flight recorder to the daemon and
    // rewrite its ring as JSON lines at PATH on every stats tick.
    let trace_dump = args.get_opt_str("trace-dump").map(|path| {
        let rec = std::sync::Arc::new(fediac::telemetry::FlightRecorder::new(
            fediac::telemetry::DEFAULT_EVENTS,
        ));
        (rec, path)
    });
    let defaults = preset
        .as_ref()
        .map(|p| p.limits.limits())
        .unwrap_or_default();
    let limits = fediac::server::JobLimits {
        host_bytes: args.get_usize("host-bytes", defaults.host_bytes)?,
        ..defaults
    };
    let down_base = preset
        .as_ref()
        .map(|p| p.down.direction())
        .unwrap_or_default();
    let down = chaos_direction_over(args, "down", down_base)?;
    let downlink_chaos = (!down.is_clean()).then_some(down);
    let chaos_seed =
        args.get_u64("chaos-seed", preset.as_ref().map(|p| p.chaos_seed).unwrap_or(0))?;
    // --io picks the event engine; default honours the preset, then
    // FEDIAC_IO, else the threaded backend (DESIGN.md §6).
    let default_io = preset
        .as_ref()
        .and_then(|p| fediac::server::IoBackend::parse(&p.io))
        .unwrap_or_else(fediac::server::IoBackend::from_env);
    let io_name = args.get_str("io", default_io.name());
    let io_backend = fediac::server::IoBackend::parse(&io_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --io '{io_name}' (threaded|reactor|fleet)"))?;
    // --cores sizes the fleet backend (0 = auto); default honours the
    // preset's deploy.cores, same precedence as --io above.
    let default_cores = preset.as_ref().map(|p| p.cores).unwrap_or(0);
    let cores = args.get_usize("cores", default_cores)?;
    Ok((
        fediac::server::ServeOptions {
            bind,
            profile,
            limits,
            downlink_chaos,
            chaos_seed,
            io_backend,
            cores,
            host_budget: None,
            trace: trace_dump.as_ref().map(|(rec, _)| std::sync::Arc::clone(rec)),
        },
        ServeTelemetry { stats_every, metrics_interval, trace_dump },
        preset,
    ))
}

/// Rewrite the flight-recorder dump file, logging (but not dying) on
/// I/O errors — telemetry must never take the daemon down.
fn rewrite_trace_dump(trace: &TraceDump) {
    if let Some((rec, path)) = trace {
        if let Err(e) = rec.dump_to(path) {
            fediac::warn!("trace dump to {path} failed: {e}");
        }
    }
}

/// Run the networked aggregation daemon until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    let bind = args.get_str("bind", "0.0.0.0:7177");
    let (opts, telemetry, preset) = serve_options_from(args, bind)?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let handle = fediac::server::serve(&opts)?;
    if let Some(p) = &preset {
        fediac::info!("preset '{}': {}", p.name, p.summary);
    }
    fediac::info!(
        "aggregation server listening on {} ({} backend; ctrl-c to stop)",
        handle.local_addr(),
        opts.io_backend.name()
    );
    // One-second ticks drive both cadences: the human-readable stats
    // line every --stats-every seconds and (when --metrics-interval > 0)
    // a machine-readable JSON-lines snapshot on stderr. The JSON goes
    // through raw eprintln, not the logger, so scrapers see bare lines.
    let mut tick = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        tick += 1;
        if telemetry.metrics_interval > 0 && tick % telemetry.metrics_interval == 0 {
            eprintln!("{}", handle.stats().to_json());
        }
        if tick % telemetry.stats_every.max(1) != 0 {
            continue;
        }
        rewrite_trace_dump(&telemetry.trace_dump);
        let s = handle.stats();
        fediac::info!(
            "pkts={} jobs={} rounds={} dup={} spill={} spill_drop={} waves={} \
             stalls={} idle_rel={} reserve_sup={} spoof={} bad_aux={} err={} pooled={} \
             pool_miss={} steered={} round_p50_us={} round_p99_us={}",
            s.packets,
            s.jobs_created,
            s.rounds_completed,
            s.duplicates,
            s.spilled,
            s.spill_dropped,
            s.waves,
            s.register_stalls,
            s.idle_releases,
            s.reserves_suppressed,
            s.downlink_spoofs,
            s.non_finite_aux,
            s.decode_errors,
            s.frames_pooled,
            s.pool_misses,
            s.steered_frames,
            s.hist_round_latency.quantile(0.50),
            s.hist_round_latency.quantile(0.99)
        );
    }
}

/// Run N collaborating shard daemons in one process until killed: shard
/// `s` listens on `--bind-base`'s port plus `s` (PROTOCOL.md §8). Point
/// clients at the full endpoint list with `fediac client --shards`.
fn cmd_shard_serve(args: &Args) -> Result<()> {
    let bind = args.get_str("bind-base", "0.0.0.0:7177");
    let (opts, telemetry, preset) = serve_options_from(args, bind)?;
    let default_shards = preset.as_ref().map(|p| p.shards as usize).unwrap_or(2);
    let n_shards = args.get_usize("shards", default_shards)?;
    let n_shards = u8::try_from(n_shards)
        .map_err(|_| anyhow::anyhow!("--shards {n_shards} out of range (max 16)"))?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    if let Some(p) = &preset {
        fediac::info!("preset '{}': {}", p.name, p.summary);
    }
    let handles = fediac::server::serve_sharded(&opts, n_shards)?;
    let endpoints: Vec<String> = handles.iter().map(|h| h.local_addr().to_string()).collect();
    for (s, addr) in endpoints.iter().enumerate() {
        fediac::info!("shard {s}/{n_shards} listening on {addr}");
    }
    fediac::info!(
        "sharded deployment up (ctrl-c to stop); clients connect with --shards {}",
        endpoints.join(",")
    );
    let mut tick = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        tick += 1;
        // One JSON line per shard per metrics interval, each tagged with
        // its shard id so scrapers can tell the streams apart.
        if telemetry.metrics_interval > 0 && tick % telemetry.metrics_interval == 0 {
            for (s, h) in handles.iter().enumerate() {
                eprintln!("{{\"shard\":{s},\"stats\":{}}}", h.stats().to_json());
            }
        }
        if tick % telemetry.stats_every.max(1) != 0 {
            continue;
        }
        rewrite_trace_dump(&telemetry.trace_dump);
        for (s, h) in handles.iter().enumerate() {
            let st = h.stats();
            fediac::info!(
                "shard {s}: pkts={} jobs={} rounds={} dup={} spill={} waves={} \
                 stalls={} err={} round_p50_us={} round_p99_us={}",
                st.packets,
                st.jobs_created,
                st.rounds_completed,
                st.duplicates,
                st.spilled,
                st.waves,
                st.register_stalls,
                st.decode_errors,
                st.hist_round_latency.quantile(0.50),
                st.hist_round_latency.quantile(0.99)
            );
        }
    }
}

/// Measure the data-plane kernels (golomb bit I/O, vote absorb, lane
/// add, thresholding, pooled frame emission) against their scalar
/// oracles and write the `BENCH_CODEC.json` artifact.
fn cmd_bench_codec(args: &Args) -> Result<()> {
    use fediac::bench_codec::{run, BenchCodecOptions};
    let mut opts =
        if args.get_flag("smoke") { BenchCodecOptions::smoke() } else { BenchCodecOptions::default() };
    opts.d = args.get_usize("d", opts.d)?;
    opts.iters = args.get_usize("iters", opts.iters)?;
    opts.density = args.get_f64("density", opts.density)?;
    opts.payload_budget = args.get_usize("payload", opts.payload_budget)?;
    opts.seed = args.get_u64("seed", opts.seed)?;
    let out_path = args.get_str("out", "BENCH_CODEC.json");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let report = run(&opts)?;
    println!("{}", report.render());
    save(&out_path, &report.to_json())?;
    Ok(())
}

/// Measure rounds/s and bytes/round for real wire rounds over loopback,
/// per I/O backend, and write the `BENCH_WIRE.json` artifact (the first
/// step of the ROADMAP "cross-machine benches" item).
fn cmd_bench_wire(args: &Args) -> Result<()> {
    use fediac::bench_wire::{run, BenchWireOptions};
    let mut opts =
        if args.get_flag("smoke") { BenchWireOptions::smoke() } else { BenchWireOptions::default() };
    opts.jobs = args.get_usize("jobs", opts.jobs)?;
    opts.rounds = args.get_usize("rounds", opts.rounds)?;
    opts.clients_per_job = args.get_u16("clients", opts.clients_per_job)?;
    opts.d = args.get_usize("d", opts.d)?;
    opts.payload_budget = args.get_usize("payload", opts.payload_budget)?;
    opts.seed = args.get_u64("seed", opts.seed)?;
    // --shards N: drive a serve_sharded deployment through the sharded
    // fan-out client and report per-shard rounds/s (d at the payload
    // budget must give every shard at least one vote block).
    let shards = args.get_usize("shards", opts.shards as usize)?;
    opts.shards = u8::try_from(shards)
        .map_err(|_| anyhow::anyhow!("--shards {shards} out of range (max 16)"))?;
    let mut profile = ps_from(args)?;
    profile.memory_bytes = args.get_usize("memory", profile.memory_bytes)?;
    opts.profile = profile;
    let io = args.get_str("io", "both");
    if io != "both" {
        let backend = fediac::server::IoBackend::parse(&io)
            .ok_or_else(|| anyhow::anyhow!("unknown --io '{io}' (both|threaded|reactor|fleet)"))?;
        opts.backends = vec![backend];
    }
    // --cores N sizes the fleet legs (0 = auto-size to the host).
    opts.cores = args.get_usize("cores", opts.cores)?;
    // --swarm: also measure the single-thread swarm multiplexer hosting
    // the same fleet (reactor daemon, ≤ --swarm-sockets sockets).
    opts.swarm = args.get_flag("swarm");
    opts.swarm_sockets = args.get_usize("swarm-sockets", opts.swarm_sockets)?;
    // --down-*: measure under seeded downlink chaos (replayable — the
    // lanes derive from --chaos-seed, default the workload seed).
    let down = chaos_direction_from(args, "down")?;
    opts.downlink_chaos = (!down.is_clean()).then_some(down);
    opts.chaos_seed = args.get_u64("chaos-seed", opts.seed)?;
    let out_path = args.get_str("out", "BENCH_WIRE.json");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let report = run(&opts)?;
    println!("{}", report.render());
    save(&out_path, &report.to_json())?;
    Ok(())
}

/// Run a standalone chaos proxy in front of an aggregation server until
/// killed. Point clients at `--listen`; datagrams relay to `--upstream`
/// with the configured per-direction loss/dup/reorder/corruption.
fn cmd_chaos(args: &Args) -> Result<()> {
    let listen = args.get_str("listen", "127.0.0.1:7178");
    let upstream = args.get_str("upstream", "127.0.0.1:7177");
    let seed = args.get_u64("seed", 1)?;
    let uplink = chaos_direction_from(args, "up")?;
    let downlink = chaos_direction_from(args, "down")?;
    let stats_every = args.get_u64("stats-every", 10)?;
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let handle = fediac::net::chaos_proxy(&fediac::net::ChaosProxyOptions {
        listen,
        upstream: upstream.clone(),
        config: fediac::net::ChaosConfig { seed, uplink, downlink },
    })?;
    fediac::info!(
        "chaos proxy on {} → {upstream} (seed {seed}; ctrl-c to stop)",
        handle.local_addr()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(stats_every.max(1)));
        let s = handle.snapshot();
        fediac::info!(
            "flows={} (rejected={}) up: fwd={} drop={} dup={} reord={} corrupt={} | \
             down: fwd={} drop={} dup={} reord={} corrupt={}",
            s.flows,
            s.flows_rejected,
            s.up.forwarded,
            s.up.dropped,
            s.up.duplicated,
            s.up.reordered,
            s.up.corrupted,
            s.down.forwarded,
            s.down.dropped,
            s.down.duplicated,
            s.down.reordered,
            s.down.corrupted
        );
    }
}

/// Either transport behind `fediac client`: one server, or the sharded
/// fan-out across the `--shards` endpoint list.
enum AnyClient {
    Single(fediac::client::FediacClient),
    Sharded(fediac::client::ShardedFediacClient),
}

impl AnyClient {
    fn run_round(
        &mut self,
        round: usize,
        update: &[f32],
    ) -> Result<fediac::client::RoundOutcome> {
        match self {
            AnyClient::Single(c) => c.run_round(round, update),
            AnyClient::Sharded(c) => c.run_round(round, update),
        }
    }

    fn stats(&self) -> fediac::client::ClientStats {
        match self {
            AnyClient::Single(c) => c.stats,
            AnyClient::Sharded(c) => c.stats(),
        }
    }
}

/// Drive one client through FediAC rounds over the wire (synthetic
/// deterministic updates; every client of a job must share --seed).
fn cmd_client(args: &Args) -> Result<()> {
    use fediac::client::{protocol, ClientOptions, FediacClient, ShardedFediacClient};
    use fediac::util::Rng;

    let server = args.get_str("server", "127.0.0.1:7177");
    let job = args.get_u32("job", 1)?;
    let client_id = args.get_u16("client-id", 0)?;
    let n_clients = args.get_u16("clients", 4)?;
    let d = args.get_usize("d", 4096)?;
    let rounds = args.get_usize("rounds", 2)?;
    let k_frac = args.get_f64("k-frac", 0.05)?;
    let mut opts = ClientOptions::new(server, job, client_id, d, n_clients);
    opts.threshold_a = args.get_u16("a", 3)?;
    opts.bits_b = args.get_usize("b", 12)?;
    opts.backend_seed = args.get_u64("seed", 7)?;
    opts.payload_budget = args.get_usize("payload", opts.payload_budget)?;
    opts.timeout = std::time::Duration::from_millis(args.get_u64("timeout-ms", 200)?);
    opts.send_loss = args.get_f64("loss", 0.0)?;
    opts.k = protocol::votes_per_client(d, k_frac);
    // --quorum Q: register a round-closure quorum (PROTOCOL.md §11).
    // 0 (the default) keeps legacy all-N rounds and the 12-byte spec.
    opts.quorum = args.get_u16("quorum", 0)?;
    // --chaos-*: run this client behind an in-process chaos proxy with
    // the same knobs applied to both directions.
    let chaos_dir = chaos_direction_from(args, "chaos")?;
    let chaos_seed = args.get_u64("chaos-seed", 1)?;
    if !chaos_dir.is_clean() {
        opts.chaos = Some(fediac::net::ChaosConfig::symmetric(chaos_seed, chaos_dir));
    }
    // --shards host:p0,host:p1,…: fan the protocol out across a sharded
    // deployment instead of a single server (endpoint s hosts slice s).
    let shard_list = args.get_opt_str("shards");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let seed = opts.backend_seed;
    let mut client = match shard_list {
        Some(list) => {
            let servers: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let c = ShardedFediacClient::connect(&servers, opts)?;
            fediac::info!(
                "job={job} client {client_id} joined across {} shards \
                 ({n_clients} clients, d={d})",
                c.n_shards()
            );
            AnyClient::Sharded(c)
        }
        None => {
            let c = FediacClient::connect(opts)?;
            fediac::info!("job={job} client {client_id} joined ({n_clients} clients, d={d})");
            AnyClient::Single(c)
        }
    };
    let mut residual = vec![0.0f32; d];
    for round in 1..=rounds {
        // Deterministic synthetic update stream (unique per client/round),
        // with the previous round's residual folded in (Algorithm 1).
        let mut rng = Rng::new(seed ^ (client_id as u64) << 32 ^ round as u64);
        let mut update: Vec<f32> = (0..d).map(|_| (rng.gaussian() * 0.01) as f32).collect();
        for (u, r) in update.iter_mut().zip(&residual) {
            *u += *r;
        }
        let out = client.run_round(round, &update)?;
        residual = out.residual;
        let l2 = out.delta.iter().map(|&x| f64::from(x) * f64::from(x)).sum::<f64>().sqrt();
        println!(
            "round {round}: k_S={} ({:.2}% of d)  f={:.1}  |delta|2={l2:.4e}  retx={}",
            out.gia_indices.len(),
            100.0 * out.gia_indices.len() as f64 / d as f64,
            out.scale_f,
            out.retransmissions
        );
    }
    let snapshots: Vec<(String, fediac::net::ChaosSnapshot)> = match &client {
        AnyClient::Single(c) => {
            c.chaos_snapshot().map(|s| ("".to_string(), s)).into_iter().collect()
        }
        AnyClient::Sharded(c) => c
            .shards()
            .iter()
            .enumerate()
            .filter_map(|(i, sc)| sc.chaos_snapshot().map(|s| (format!(" shard {i}"), s)))
            .collect(),
    };
    for (label, snap) in snapshots {
        fediac::info!(
            "job={job} chaos{label}: up drop={} dup={} reord={} corrupt={} | \
             down drop={} dup={} reord={} corrupt={}",
            snap.up.dropped,
            snap.up.duplicated,
            snap.up.reordered,
            snap.up.corrupted,
            snap.down.dropped,
            snap.down.duplicated,
            snap.down.reordered,
            snap.down.corrupted
        );
    }
    let s = client.stats();
    fediac::info!(
        "job={job} client {client_id} done: retx={} dropped={} polls={} rejoins={} \
         resets={} pending_dropped={} vote_p99_us={} update_p99_us={}",
        s.retransmissions,
        s.dropped_sends,
        s.polls,
        s.rejoins,
        s.stream_resets,
        s.pending_dropped,
        s.vote_rtt_us.quantile(0.99),
        s.update_rtt_us.quantile(0.99)
    );
    Ok(())
}

/// Host a fleet of simulated clients on ONE thread over a handful of
/// sockets (the swarm multiplexer) against a running aggregation server.
fn cmd_swarm(args: &Args) -> Result<()> {
    use fediac::client::swarm::{self, SwarmOptions};

    // --preset: a deployment preset's [mix] supplies the fleet shape
    // and its [chaos.up] the uplink fault defaults; flags override.
    let preset = preset_from(args)?;
    let mix = preset.as_ref().map(|p| p.mix.clone());
    let server = args.get_str("server", "127.0.0.1:7177");
    let clients = args.get_usize(
        "clients",
        mix.as_ref().map(|m| m.swarm_clients).unwrap_or(10_000),
    )?;
    let per_job = args.get_u16(
        "clients-per-job",
        mix.as_ref().map(|m| m.clients_per_job).unwrap_or(64),
    )?;
    let d = args.get_usize("d", mix.as_ref().map(|m| m.d).unwrap_or(1024))?;
    let seed = args.get_u64("seed", 7)?;
    let mut opts = SwarmOptions::new(server, d);
    opts.rounds = args.get_usize("rounds", mix.as_ref().map(|m| m.rounds).unwrap_or(1))?;
    opts.sockets = args.get_usize(
        "sockets",
        mix.as_ref().map(|m| m.swarm_sockets).unwrap_or(swarm::MAX_SWARM_SOCKETS),
    )?;
    opts.threshold_a =
        args.get_u16("a", mix.as_ref().map(|m| m.threshold_a).unwrap_or(3))?;
    opts.bits_b = args.get_usize("b", mix.as_ref().map(|m| m.bits_b).unwrap_or(opts.bits_b))?;
    let k_frac = args.get_f64("k-frac", mix.as_ref().map(|m| m.k_frac).unwrap_or(0.05))?;
    opts.k = fediac::client::protocol::votes_per_client(d, k_frac);
    opts.payload_budget = args.get_usize(
        "payload",
        mix.as_ref().map(|m| m.payload).unwrap_or(opts.payload_budget),
    )?;
    opts.timeout = std::time::Duration::from_millis(
        args.get_u64("timeout-ms", mix.as_ref().map(|m| m.timeout_ms).unwrap_or(200))?,
    );
    opts.max_retries =
        args.get_usize("max-retries", mix.as_ref().map(|m| m.max_retries).unwrap_or(50))?;
    // --chaos-*: seeded uplink chaos on the swarm sockets, replayable
    // from --chaos-seed (default: the workload seed, so one --seed
    // replays workload AND faults).
    let up_base = preset.as_ref().map(|p| p.up.direction()).unwrap_or_default();
    let up = chaos_direction_over(args, "chaos", up_base)?;
    opts.uplink_chaos = (!up.is_clean()).then_some(up);
    opts.chaos_seed = args.get_u64(
        "chaos-seed",
        preset.as_ref().map(|p| p.chaos_seed).unwrap_or(seed),
    )?;
    // --quorum Q (default: the preset's mix.quorum): quorum rounds per
    // PROTOCOL.md §11. A preset with a live [churn] section also arms
    // the client-churn plane — kills, stale rejoins, flash crowds —
    // seeded from the same chaos seed, so one seed replays the run.
    opts.quorum =
        args.get_u16("quorum", mix.as_ref().map(|m| m.quorum).unwrap_or(0))?;
    opts.churn = preset
        .as_ref()
        .filter(|p| !p.churn.is_quiet())
        .map(|p| p.churn.config());
    opts.jobs = swarm::plan_fleet(clients, per_job, seed);
    let json_out = args.get_opt_str("json");
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let report = swarm::run(&opts)?;
    let s = &report.stats;
    println!(
        "# fediac swarm: {} clients / {} jobs / {} sockets / {} rounds\n\
         wall_s\tclient_rounds\trounds/s\tretx\tpending_drop\tp50_us\tp99_us\tmax_us\n\
         {:.3}\t{}\t{:.1}\t{}\t{}\t{}\t{}\t{}",
        report.clients_hosted,
        report.jobs,
        report.sockets_used,
        opts.rounds,
        report.wall_s,
        report.rounds_completed,
        report.rounds_completed as f64 / report.wall_s,
        s.retransmissions,
        s.pending_dropped,
        report.round_latency.quantile(0.50),
        report.round_latency.quantile(0.99),
        report.round_latency.max
    );
    if opts.churn.is_some() {
        let c = &report.churn;
        println!(
            "# churn: kills={} rejoins={} permanent={} flash_joins={} stranded={}",
            c.kills, c.rejoins, c.permanent_deaths, c.flash_joins, c.stranded
        );
    }
    if let Some(path) = json_out {
        let h = &report.round_latency;
        let json = format!(
            "{{\"clients_hosted\": {}, \"jobs\": {}, \"sockets\": {}, \"rounds\": {}, \
             \"wall_s\": {:.6}, \"client_rounds\": {}, \"rounds_per_s\": {:.3}, \
             \"retransmissions\": {}, \"pending_dropped\": {}, \
             \"round_latency_us\": {{\"count\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \
             \"max\": {}}}}}\n",
            report.clients_hosted,
            report.jobs,
            report.sockets_used,
            opts.rounds,
            report.wall_s,
            report.rounds_completed,
            report.rounds_completed as f64 / report.wall_s,
            s.retransmissions,
            s.pending_dropped,
            h.count(),
            h.quantile(0.50),
            h.quantile(0.90),
            h.quantile(0.99),
            h.max
        );
        save(&path, &json)?;
    }
    Ok(())
}

/// Run randomized preset×chaos×backend soak episodes until the episode
/// or duration budget runs out, appending one JSON ledger line per
/// episode (see `fediac::soak`).
fn cmd_soak(args: &Args) -> Result<()> {
    let defaults = fediac::soak::SoakOptions::default();
    let episode_seed = match args.get_opt_str("episode-seed") {
        Some(s) => Some(
            s.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--episode-seed '{s}' is not a u64"))?,
        ),
        None => None,
    };
    let presets = match args.get_opt_str("presets") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => defaults.presets.clone(),
    };
    let opts = fediac::soak::SoakOptions {
        episodes: args.get_usize("episodes", defaults.episodes)?,
        duration_s: args.get_f64("duration", defaults.duration_s)?,
        seed: args.get_u64("seed", defaults.seed)?,
        episode_seed,
        presets,
        out: args.get_str("out", &defaults.out),
    };
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let report = fediac::soak::run(&opts)?;
    fediac::info!(
        "soak passed: {} episode(s) in {:.1} s (ledger at {})",
        report.episodes,
        report.wall_s,
        opts.out
    );
    Ok(())
}

/// Compare fresh bench JSONs against committed baselines and exit
/// nonzero on any tolerance-band violation (see `fediac::trendgate`).
/// Refresh the baseline with `cp BENCH_WIRE.json bench_baseline.json`.
fn cmd_trend_gate(args: &Args) -> Result<()> {
    use fediac::trendgate::{gate_codec, gate_wire, GateConfig};

    fn load_json(path: &str) -> Result<fediac::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        fediac::util::json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
    }

    let baseline_path = args.get_str("baseline", "bench_baseline.json");
    let current_path = args.get_str("current", "BENCH_WIRE.json");
    let codec_baseline = args.get_opt_str("baseline-codec");
    let codec_current = args.get_opt_str("current-codec");
    let defaults = GateConfig::default();
    let cfg = GateConfig {
        max_throughput_drop: args.get_f64("tol-throughput", defaults.max_throughput_drop)?,
        max_latency_ratio: args.get_f64("tol-latency", defaults.max_latency_ratio)?,
    };
    args.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut findings = gate_wire(&load_json(&baseline_path)?, &load_json(&current_path)?, &cfg)?;
    match (&codec_baseline, &codec_current) {
        (Some(bp), Some(cp)) => {
            findings.extend(gate_codec(&load_json(bp)?, &load_json(cp)?, &cfg)?);
        }
        (None, None) => {}
        _ => anyhow::bail!("--baseline-codec and --current-codec must be given together"),
    }
    for f in &findings {
        eprintln!("TREND-GATE FAIL: {f}");
    }
    if !findings.is_empty() {
        anyhow::bail!(
            "{} perf regression(s) beyond tolerance (throughput drop > {:.0}% or p99 > {:.1}x); \
             if intentional, refresh with: cp {current_path} {baseline_path}",
            findings.len(),
            100.0 * cfg.max_throughput_drop,
            cfg.max_latency_ratio
        );
    }
    println!(
        "trend-gate OK: {current_path} within tolerance of {baseline_path} \
         (throughput drop <= {:.0}%, p99 <= {:.1}x)",
        100.0 * cfg.max_throughput_drop,
        cfg.max_latency_ratio
    );
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: fediac <train|fig2|table|fig3|fig4|theory|serve|shard-serve|client|swarm|chaos|\
         soak|trend-gate|bench-wire|bench-codec> [options]\n\
         see README.md for the option reference"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!("{e}"))?;
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("table") => cmd_table(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("fig4") => cmd_fig4(&args),
        Some("theory") => cmd_theory(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard-serve") => cmd_shard_serve(&args),
        Some("client") => cmd_client(&args),
        Some("swarm") => cmd_swarm(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("soak") => cmd_soak(&args),
        Some("trend-gate") => cmd_trend_gate(&args),
        Some("bench-wire") => cmd_bench_wire(&args),
        Some("bench-codec") => cmd_bench_codec(&args),
        _ => usage(),
    }
}
