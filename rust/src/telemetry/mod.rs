//! Telemetry plane: latency distributions and a protocol flight
//! recorder, threaded through every layer **without touching the wire
//! format or the allocation-free hot path**.
//!
//! * [`hist`] — lock-free log2-bucketed histograms ([`Hist`]) and their
//!   plain mergeable snapshots ([`HistSummary`]), property-tested
//!   against an exact sorted-vector oracle.
//! * [`recorder`] — the [`FlightRecorder`]: a fixed-capacity ring of
//!   protocol events with zero steady-state allocation, dumpable as
//!   JSON lines.
//!
//! The consumers live elsewhere: `server::Job` times its phases with
//! the `now` it already receives and records frame verdicts;
//! `ServerStats`/`StatsSnapshot` and `ClientStats` carry histogram
//! summaries; `bench-wire` turns per-round latencies into the
//! p50/p99/max columns of BENCH_WIRE.json.

pub mod hist;
pub mod recorder;

pub use hist::{bucket_ceil, bucket_of, oracle_quantile, Hist, HistSummary, N_BUCKETS};
pub use recorder::{FlightRecorder, PanicDump, TraceEvent, TraceNote, DEFAULT_EVENTS};
