//! Lock-free log2-bucketed latency histograms.
//!
//! One bucket per power of two: bucket 0 holds the value 0 and bucket
//! `k ≥ 1` holds `[2^(k-1), 2^k)`, so 65 buckets cover the full `u64`
//! range with a fixed-size array and ≤ 2× relative quantile error. Two
//! forms share the layout:
//!
//! * [`Hist`] — atomic buckets for concurrent recording on the data
//!   plane (one relaxed `fetch_add` per sample, no locks, no
//!   allocation).
//! * [`HistSummary`] — a plain `Copy` snapshot that merges, compares
//!   (`Eq`), travels inside `StatsSnapshot`/`ClientStats`, and answers
//!   quantile queries.
//!
//! The accuracy contract is pinned by [`oracle_quantile`], the exact
//! sorted-vector nearest-rank percentile kept in-tree (the repo's
//! oracle culture): a histogram quantile always lands in the same
//! power-of-two bucket as the oracle value, never below it, and never
//! above the recorded maximum. The maximum itself is tracked exactly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 buckets: the value 0 plus one bucket per power of two.
pub const N_BUCKETS: usize = 65;

/// Bucket index of a value: 0 for 0, else `floor(log2(v)) + 1`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Largest value a bucket can hold (`u64::MAX` for the top bucket).
#[inline]
pub fn bucket_ceil(bucket: usize) -> u64 {
    match bucket {
        0 => 0,
        1..=63 => (1u64 << bucket) - 1,
        _ => u64::MAX,
    }
}

/// Exact nearest-rank quantile over an ascending-sorted slice: the
/// smallest element with at least `⌈q·n⌉` elements ≤ it (0 on empty
/// input). This is the scalar oracle the histogram is property-tested
/// against.
pub fn oracle_quantile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Plain log2-bucketed histogram snapshot: recordable, mergeable,
/// `Copy`, and byte-for-byte comparable (`Eq`) so it can ride inside
/// the repo's stats structs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSummary {
    /// Sample count per log2 bucket (see [`bucket_of`]).
    pub buckets: [u64; N_BUCKETS],
    /// Saturating sum of all recorded values.
    pub sum: u64,
    /// Exact maximum recorded value (0 when empty).
    pub max: u64,
}

impl Default for HistSummary {
    fn default() -> Self {
        HistSummary { buckets: [0; N_BUCKETS], sum: 0, max: 0 }
    }
}

impl HistSummary {
    /// Record one value.
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Record a duration in microseconds (saturating past `u64::MAX` µs).
    pub fn record_micros(&mut self, d: Duration) {
        self.record(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&b| b == 0)
    }

    /// Fold another summary into this one (element-wise bucket add,
    /// saturating sum, max of maxima).
    pub fn merge(&mut self, other: &HistSummary) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b = b.saturating_add(*o);
        }
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile estimate, `q ∈ [0, 1]`: the ceiling of the
    /// bucket holding the rank, clamped to the exact recorded maximum
    /// (0 when empty). `quantile(1.0)` is therefore the exact max.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (bucket, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_ceil(bucket).min(self.max);
            }
        }
        self.max
    }
}

/// Lock-free histogram for concurrent recording: atomic buckets with
/// relaxed ordering, an atomic sum (wrapping in theory; overflowing it
/// would take ~585 millennia of recorded microseconds), and an exact
/// `fetch_max` maximum.
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Hist {
    /// Record one value (lock-free, allocation-free).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in microseconds (saturating past `u64::MAX` µs).
    pub fn record_micros(&self, d: Duration) {
        self.record(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Materialise a mergeable/queryable snapshot of the current state.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    /// Values spread across all magnitudes: a raw u64 right-shifted by a
    /// uniform 0..64 amount hits every bucket with similar probability.
    fn gen_values(rng: &mut crate::util::Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64() >> rng.below(65).min(63)).collect()
    }

    fn summarize(values: &[u64]) -> HistSummary {
        let mut h = HistSummary::default();
        for &v in values {
            h.record(v);
        }
        h
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        assert_eq!(bucket_ceil(0), 0);
        assert_eq!(bucket_ceil(1), 1);
        assert_eq!(bucket_ceil(2), 3);
        assert_eq!(bucket_ceil(64), u64::MAX);
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1 << 20, u64::MAX - 1, u64::MAX] {
            assert!(v <= bucket_ceil(bucket_of(v)), "{v}");
        }
    }

    #[test]
    fn empty_histogram_answers_zero() {
        let h = HistSummary::default();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.max, 0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0);
        }
    }

    #[test]
    fn quantile_tracks_sorted_oracle_bucket() {
        prop::check("hist_quantile_vs_oracle", prop::default_cases(), |rng| {
            let n = rng.below(400);
            let values = gen_values(rng, n);
            let h = summarize(&values);
            let mut sorted = values.clone();
            sorted.sort_unstable();
            prop_assert!(h.count() == n as u64, "count {} != {n}", h.count());
            prop_assert!(
                h.max == sorted.last().copied().unwrap_or(0),
                "max {} != {:?}",
                h.max,
                sorted.last()
            );
            for q in [0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let exact = oracle_quantile(&sorted, q);
                let est = h.quantile(q);
                if n == 0 {
                    prop_assert!(est == 0, "empty quantile {est}");
                    continue;
                }
                prop_assert!(
                    bucket_of(est) == bucket_of(exact) && est >= exact && est <= h.max,
                    "q={q}: est {est} vs oracle {exact} (buckets {} vs {})",
                    bucket_of(est),
                    bucket_of(exact)
                );
            }
            prop_assert!(h.quantile(1.0) == h.max, "p100 must be the exact max");
            Ok(())
        });
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        prop::check("hist_single_sample_exact", prop::default_cases(), |rng| {
            // Include both u64 extremes alongside random magnitudes.
            let v = match rng.below(8) {
                0 => 0,
                1 => u64::MAX,
                _ => rng.next_u64() >> rng.below(64),
            };
            let h = summarize(&[v]);
            for q in [0.0, 0.5, 0.99, 1.0] {
                prop_assert!(h.quantile(q) == v, "q={q}: {} != {v}", h.quantile(q));
            }
            prop_assert!(h.max == v && h.sum == v && h.count() == 1, "scalar fields");
            Ok(())
        });
    }

    #[test]
    fn merge_equals_concatenation() {
        prop::check("hist_merge_is_concat", prop::default_cases(), |rng| {
            let a = gen_values(rng, rng.below(200));
            let b = gen_values(rng, rng.below(200));
            let mut merged = summarize(&a);
            merged.merge(&summarize(&b));
            let mut both = a.clone();
            both.extend_from_slice(&b);
            prop_assert!(merged == summarize(&both), "merge must equal concatenation");
            Ok(())
        });
    }

    #[test]
    fn n_way_merge_matches_the_union_of_samples_oracle() {
        // The multi-shard / multi-client aggregation shape: K independent
        // histograms folded into one must behave exactly as if one
        // histogram had recorded the union of all samples — bucket
        // counts, sum, max, and every quantile against the sorted-union
        // oracle. Folding order must not matter.
        prop::check("hist_n_way_merge_vs_union", prop::default_cases(), |rng| {
            let k = 2 + rng.below(7);
            let parts: Vec<Vec<u64>> = (0..k).map(|_| gen_values(rng, rng.below(120))).collect();
            let mut forward = HistSummary::default();
            for p in &parts {
                forward.merge(&summarize(p));
            }
            let mut reverse = HistSummary::default();
            for p in parts.iter().rev() {
                reverse.merge(&summarize(p));
            }
            let mut union: Vec<u64> = parts.iter().flatten().copied().collect();
            let direct = summarize(&union);
            prop_assert!(forward == direct, "{k}-way merge != union summary");
            prop_assert!(forward == reverse, "{k}-way merge is order-sensitive");
            union.sort_unstable();
            for q in [0.25, 0.5, 0.9, 0.99, 1.0] {
                let exact = oracle_quantile(&union, q);
                let est = forward.quantile(q);
                if union.is_empty() {
                    prop_assert!(est == 0, "empty union quantile {est}");
                    continue;
                }
                prop_assert!(
                    bucket_of(est) == bucket_of(exact) && est >= exact && est <= forward.max,
                    "q={q}: merged est {est} vs union oracle {exact}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn saturating_samples_stay_exact_at_the_top() {
        let mut h = HistSummary::default();
        h.record(u64::MAX);
        h.record(u64::MAX);
        h.record(1 << 63);
        // The sum saturates instead of wrapping; max and quantiles stay exact.
        assert_eq!(h.sum, u64::MAX);
        assert_eq!(h.max, u64::MAX);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(1.0), u64::MAX);
        assert_eq!(bucket_of(h.quantile(0.1)), 64);
        // Saturating durations land in the top bucket too.
        h.record_micros(Duration::MAX);
        assert_eq!(h.buckets[64], 4);
    }

    #[test]
    fn atomic_hist_matches_plain_summary() {
        prop::check("hist_atomic_matches_plain", prop::default_cases(), |rng| {
            let values = gen_values(rng, rng.below(300));
            let atomic = Hist::default();
            for &v in &values {
                atomic.record(v);
            }
            prop_assert!(atomic.summary() == summarize(&values), "atomic != plain");
            Ok(())
        });
    }

    #[test]
    fn concurrent_recording_is_lossless() {
        let h = std::sync::Arc::new(Hist::default());
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let h = std::sync::Arc::clone(&h);
                scope.spawn(move || {
                    for i in 0..10_000u64 {
                        h.record((t + 1) << (i % 8));
                    }
                });
            }
        });
        let s = h.summary();
        assert_eq!(s.count(), 40_000);
        assert_eq!(s.max, 4 << 7);
    }
}
