//! Protocol flight recorder: a fixed-capacity ring of the last N
//! protocol events (timestamp, job, round, frame kind, peer,
//! accept/drop verdict), recorded by the sans-I/O [`Job`] and the
//! dispatch path of both I/O backends.
//!
//! Design constraints, in order:
//!
//! 1. **Zero steady-state allocation.** Events are plain `Copy` records
//!    written into a pre-allocated ring; once the ring is full, new
//!    events overwrite the oldest. Nothing on the data path formats a
//!    string or grows a buffer.
//! 2. **Cheap enough to leave on.** One short mutex hold per event (the
//!    ring is shared across worker threads); recording is optional —
//!    a `Job` without a recorder attached pays a single branch.
//! 3. **Dumpable after the fact.** [`FlightRecorder::to_json_lines`]
//!    renders the ring oldest-first as JSON lines for `fediac serve
//!    --trace-dump <path>`, and [`FlightRecorder::dump_on_panic`]
//!    arms a guard that prints the ring to stderr when a test thread
//!    panics mid-round — the black box for chaos-run post-mortems.
//!
//! Telemetry is observational only: nothing here is wire-visible
//! (PROTOCOL.md conformance map).
//!
//! [`Job`]: crate::server::Job

use std::fmt::Write as _;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::wire::WireKind;

/// Default ring capacity used by `fediac serve --trace-dump`.
pub const DEFAULT_EVENTS: usize = 4096;

/// The verdict a recorded protocol event carries: what the server did
/// with the frame (or why it refused it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceNote {
    /// Data block validated and folded into the round state.
    Accepted,
    /// This frame completed phase 1 (GIA multicast follows).
    PhaseOneDone,
    /// This frame completed the round (aggregate multicast follows).
    RoundDone,
    /// Redundant frame (retransmission, already-counted block, or data
    /// for a closed phase); dropped without effect.
    Duplicate,
    /// Malformed geometry or protocol-order violation; dropped.
    BadFrame,
    /// Out-of-window block parked in the host spill buffer.
    Spilled,
    /// Out-of-window block dropped because the spill buffer is full.
    SpillDropped,
    /// Vote frame with a non-finite local-max aux; dropped.
    NonFiniteAux,
    /// Server-only frame kind arriving on the uplink; dropped.
    DownlinkSpoof,
    /// Join accepted (ack carries the agreed spec).
    JoinAccepted,
    /// Join refused (spec mismatch, bad geometry, or capacity).
    JoinRefused,
    /// Poll answered with the requested phase result.
    PollServed,
    /// Poll answered with `NotReady` (phase still open).
    NotReady,
    /// Poll ignored: the source exhausted its re-serve budget.
    PollSuppressed,
    /// Frame for a job this daemon has no state for.
    UnknownJob,
    /// Datagram the front door could not parse.
    DecodeError,
    /// Join refused because the daemon is at its job cap.
    CapRejected,
    /// Straggler data frame for a phase the server already closed
    /// (quorum close or normal completion); dropped without effect.
    LateAfterClose,
    /// A phase deadline expired with the quorum met and the server
    /// force-closed the phase without the remaining clients.
    QuorumClose,
}

impl TraceNote {
    /// Stable snake_case name used in the JSON dump.
    pub fn name(&self) -> &'static str {
        match self {
            TraceNote::Accepted => "accepted",
            TraceNote::PhaseOneDone => "phase1_done",
            TraceNote::RoundDone => "round_done",
            TraceNote::Duplicate => "duplicate",
            TraceNote::BadFrame => "bad_frame",
            TraceNote::Spilled => "spilled",
            TraceNote::SpillDropped => "spill_dropped",
            TraceNote::NonFiniteAux => "non_finite_aux",
            TraceNote::DownlinkSpoof => "downlink_spoof",
            TraceNote::JoinAccepted => "join_accepted",
            TraceNote::JoinRefused => "join_refused",
            TraceNote::PollServed => "poll_served",
            TraceNote::NotReady => "not_ready",
            TraceNote::PollSuppressed => "poll_suppressed",
            TraceNote::UnknownJob => "unknown_job",
            TraceNote::DecodeError => "decode_error",
            TraceNote::CapRejected => "cap_rejected",
            TraceNote::LateAfterClose => "late_after_close",
            TraceNote::QuorumClose => "quorum_close",
        }
    }
}

/// One recorded protocol event. Plain `Copy` data — building one never
/// allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Microseconds since the recorder was created.
    pub at_us: u64,
    /// Job the event belongs to (0 when unknown, e.g. decode errors).
    pub job: u32,
    /// Round the event belongs to (0 when not applicable).
    pub round: u32,
    /// Frame kind that triggered the event; `None` when the datagram
    /// never parsed far enough to have one.
    pub kind: Option<WireKind>,
    /// Claimed client id (`u16::MAX` when unknown).
    pub client: u16,
    /// Source address, where the recording site knows it.
    pub peer: Option<SocketAddr>,
    /// What the server did with the frame.
    pub note: TraceNote,
}

#[derive(Debug)]
struct Ring {
    buf: Vec<TraceEvent>,
    next: usize,
    total: u64,
}

/// Shared fixed-capacity event ring. Clone the `Arc` freely; all
/// recording sites append into the same ring.
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    capacity: usize,
    ring: Mutex<Ring>,
}

impl FlightRecorder {
    /// A recorder keeping the most recent `capacity` events (min 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        FlightRecorder {
            epoch: Instant::now(),
            capacity,
            ring: Mutex::new(Ring { buf: Vec::with_capacity(capacity), next: 0, total: 0 }),
        }
    }

    /// The instant event timestamps are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds between the epoch and `now` (0 for pre-epoch instants).
    pub fn stamp(&self, now: Instant) -> u64 {
        u64::try_from(now.saturating_duration_since(self.epoch).as_micros()).unwrap_or(u64::MAX)
    }

    /// Append one event, overwriting the oldest once the ring is full.
    pub fn record(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < self.capacity {
            ring.buf.push(ev);
        } else {
            let slot = ring.next;
            ring.buf[slot] = ev;
        }
        ring.next = (ring.next + 1) % self.capacity;
        ring.total += 1;
    }

    /// Compose and append one event stamped at `now`.
    #[allow(clippy::too_many_arguments)]
    pub fn note(
        &self,
        job: u32,
        round: u32,
        kind: Option<WireKind>,
        client: u16,
        peer: Option<SocketAddr>,
        note: TraceNote,
        now: Instant,
    ) {
        self.record(TraceEvent { at_us: self.stamp(now), job, round, kind, client, peer, note });
    }

    /// Events currently held, oldest first (allocates; dump path only).
    pub fn events(&self) -> Vec<TraceEvent> {
        let ring = self.ring.lock().unwrap();
        if ring.buf.len() < self.capacity {
            ring.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.capacity);
            out.extend_from_slice(&ring.buf[ring.next..]);
            out.extend_from_slice(&ring.buf[..ring.next]);
            out
        }
    }

    /// Number of events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn total_recorded(&self) -> u64 {
        self.ring.lock().unwrap().total
    }

    /// Render the ring as JSON lines, oldest event first.
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            let _ = write!(
                out,
                "{{\"at_us\":{},\"job\":{},\"round\":{},\"kind\":",
                ev.at_us, ev.job, ev.round
            );
            match ev.kind {
                Some(k) => {
                    let _ = write!(out, "\"{k:?}\"");
                }
                None => out.push_str("null"),
            }
            let _ = write!(out, ",\"client\":{},\"peer\":", ev.client);
            match ev.peer {
                Some(p) => {
                    let _ = write!(out, "\"{p}\"");
                }
                None => out.push_str("null"),
            }
            let _ = writeln!(out, ",\"note\":\"{}\"}}", ev.note.name());
        }
        out
    }

    /// Write the JSON-lines dump to `path` (whole-file rewrite).
    pub fn dump_to(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json_lines())
    }

    /// Arm a guard that dumps this recorder to stderr if the current
    /// thread unwinds with a panic while the guard is live — gives
    /// failing wire tests an automatic protocol post-mortem.
    pub fn dump_on_panic(self: &Arc<Self>) -> PanicDump {
        PanicDump(Arc::clone(self))
    }
}

/// Drop guard from [`FlightRecorder::dump_on_panic`].
#[derive(Debug)]
pub struct PanicDump(Arc<FlightRecorder>);

impl Drop for PanicDump {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "--- flight recorder: last {} of {} events ---\n{}--- end flight recorder ---",
                self.0.len(),
                self.0.total_recorded(),
                self.0.to_json_lines()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use std::time::Duration;

    fn ev(at_us: u64, note: TraceNote) -> TraceEvent {
        TraceEvent {
            at_us,
            job: 7,
            round: 3,
            kind: Some(WireKind::Vote),
            client: 1,
            peer: Some("127.0.0.1:4000".parse().unwrap()),
            note,
        }
    }

    #[test]
    fn ring_keeps_the_most_recent_events_in_order() {
        let rec = FlightRecorder::new(4);
        for i in 0..6 {
            rec.record(ev(i, TraceNote::Accepted));
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.total_recorded(), 6);
        let at: Vec<u64> = rec.events().iter().map(|e| e.at_us).collect();
        assert_eq!(at, vec![2, 3, 4, 5], "oldest-first, pre-wrap events evicted");
    }

    #[test]
    fn stamps_are_monotonic_from_the_epoch() {
        let rec = FlightRecorder::new(8);
        let e = rec.epoch();
        assert_eq!(rec.stamp(e), 0);
        assert_eq!(rec.stamp(e - Duration::from_secs(1)), 0, "pre-epoch clamps to 0");
        assert_eq!(rec.stamp(e + Duration::from_millis(3)), 3_000);
    }

    #[test]
    fn json_lines_parse_and_carry_every_field() {
        let rec = FlightRecorder::new(8);
        rec.record(ev(11, TraceNote::Duplicate));
        rec.record(TraceEvent {
            at_us: 12,
            job: 0,
            round: 0,
            kind: None,
            client: u16::MAX,
            peer: None,
            note: TraceNote::DecodeError,
        });
        let dump = rec.to_json_lines();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = json::parse(lines[0]).unwrap();
        assert_eq!(first.get("at_us").unwrap().as_usize(), Some(11));
        assert_eq!(first.get("job").unwrap().as_usize(), Some(7));
        assert_eq!(first.get("round").unwrap().as_usize(), Some(3));
        assert_eq!(first.get("kind").unwrap().as_str(), Some("Vote"));
        assert_eq!(first.get("client").unwrap().as_usize(), Some(1));
        assert_eq!(first.get("peer").unwrap().as_str(), Some("127.0.0.1:4000"));
        assert_eq!(first.get("note").unwrap().as_str(), Some("duplicate"));
        let second = json::parse(lines[1]).unwrap();
        assert_eq!(second.get("kind").unwrap(), &json::Json::Null);
        assert_eq!(second.get("peer").unwrap(), &json::Json::Null);
        assert_eq!(second.get("note").unwrap().as_str(), Some("decode_error"));
    }

    #[test]
    fn panic_guard_is_silent_on_clean_drop() {
        let rec = Arc::new(FlightRecorder::new(4));
        rec.record(ev(1, TraceNote::Accepted));
        let _guard = rec.dump_on_panic();
        // Dropping without a panic must not print or disturb the ring.
        drop(_guard);
        assert_eq!(rec.len(), 1);
    }

    #[test]
    fn concurrent_recording_never_loses_counts() {
        let rec = Arc::new(FlightRecorder::new(64));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let rec = Arc::clone(&rec);
                scope.spawn(move || {
                    for i in 0..1000 {
                        rec.record(ev(i, TraceNote::Accepted));
                    }
                });
            }
        });
        assert_eq!(rec.total_recorded(), 4000);
        assert_eq!(rec.len(), 64);
    }
}
