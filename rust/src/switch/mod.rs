//! Programmable-switch (PS) simulator: register memory, integer ALU,
//! scoreboard, and the two aggregation programs (vote counting + integer
//! accumulation) all in-network FL algorithms in this repo run on.

pub mod alu;
pub mod memory;
pub mod scoreboard;
#[allow(clippy::module_inception)]
pub mod switch;

pub use memory::{window_blocks, MemError, RegisterFile};
pub use scoreboard::{Mark, Scoreboard};
pub use switch::{
    advertised_window, waves_needed, ProgrammableSwitch, SwitchStats, UpdateAggregator,
    VoteAggregator,
};
