//! Per-block contribution scoreboard.
//!
//! SwitchML-style switches keep a scoreboard marking which workers have
//! contributed to each aggregation slot so that (a) duplicates from
//! retransmission are dropped and (b) a slot's registers can be freed and
//! its aggregate broadcast the moment all N contributions are in (§II
//! "In-Network FL": scoreboard mechanism + end-host retransmission).

/// Tracks, per aggregation block, which clients have contributed.
#[derive(Debug, Clone)]
pub struct Scoreboard {
    n_clients: usize,
    /// One u64 mask per block (supports up to 64 clients; the paper's
    /// system scales N ∈ [20, 50]).
    masks: Vec<u64>,
    complete: Vec<bool>,
}

/// How an incoming contribution should be treated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mark {
    /// First contribution from this client for this block.
    Fresh,
    /// Duplicate (retransmission) — must not be aggregated again.
    Duplicate,
    /// This contribution completed the block (all N clients seen).
    Completed,
}

impl Scoreboard {
    /// Empty board for `n_blocks` blocks × `n_clients` clients (≤ 64).
    pub fn new(n_blocks: usize, n_clients: usize) -> Self {
        assert!(n_clients <= 64, "scoreboard supports up to 64 clients");
        assert!(n_clients > 0);
        Scoreboard { n_clients, masks: vec![0; n_blocks], complete: vec![false; n_blocks] }
    }

    /// Record a contribution. Returns how the packet should be treated.
    pub fn mark(&mut self, block: usize, client: usize) -> Mark {
        debug_assert!(client < self.n_clients);
        let bit = 1u64 << client;
        if self.masks[block] & bit != 0 {
            return Mark::Duplicate;
        }
        self.masks[block] |= bit;
        if self.masks[block].count_ones() as usize == self.n_clients {
            self.complete[block] = true;
            Mark::Completed
        } else {
            Mark::Fresh
        }
    }

    /// True when `block` has every client's contribution.
    pub fn is_complete(&self, block: usize) -> bool {
        self.complete[block]
    }

    /// Number of contributions received for a block.
    pub fn contributions(&self, block: usize) -> usize {
        self.masks[block].count_ones() as usize
    }

    /// Blocks tracked.
    pub fn n_blocks(&self) -> usize {
        self.masks.len()
    }

    /// All blocks complete?
    pub fn all_complete(&self) -> bool {
        self.complete.iter().all(|&c| c)
    }

    /// Reset for reuse in the next round/phase.
    pub fn reset(&mut self, n_blocks: usize) {
        self.masks.clear();
        self.masks.resize(n_blocks, 0);
        self.complete.clear();
        self.complete.resize(n_blocks, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_then_complete() {
        let mut sb = Scoreboard::new(2, 3);
        assert_eq!(sb.mark(0, 0), Mark::Fresh);
        assert_eq!(sb.mark(0, 1), Mark::Fresh);
        assert_eq!(sb.mark(0, 2), Mark::Completed);
        assert!(sb.is_complete(0));
        assert!(!sb.is_complete(1));
        assert!(!sb.all_complete());
        sb.mark(1, 0);
        sb.mark(1, 1);
        sb.mark(1, 2);
        assert!(sb.all_complete());
    }

    #[test]
    fn duplicates_detected() {
        let mut sb = Scoreboard::new(1, 4);
        assert_eq!(sb.mark(0, 2), Mark::Fresh);
        assert_eq!(sb.mark(0, 2), Mark::Duplicate);
        assert_eq!(sb.contributions(0), 1);
    }

    #[test]
    fn reset_clears_state() {
        let mut sb = Scoreboard::new(1, 2);
        sb.mark(0, 0);
        sb.mark(0, 1);
        assert!(sb.all_complete());
        sb.reset(3);
        assert_eq!(sb.n_blocks(), 3);
        assert!(!sb.is_complete(0));
        assert_eq!(sb.contributions(0), 0);
    }

    #[test]
    #[should_panic]
    fn too_many_clients_rejected() {
        let _ = Scoreboard::new(1, 65);
    }
}
