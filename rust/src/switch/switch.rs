//! The programmable switch: per-packet service model + aggregation programs.
//!
//! Two data-plane programs cover every algorithm in the paper:
//!
//! * [`VoteAggregator`] — FediAC phase 1: add packed 0-1 vote arrays into
//!   u16 counters, then threshold with `a` to produce the GIA (§IV step 2).
//! * [`UpdateAggregator`] — FediAC phase 2 and the SwitchML/OmniReduce/libra
//!   hot path: lane-wise i32 accumulation of aligned packet payloads.
//!
//! Timing follows §V-A2: each arriving packet costs one aggregation
//! operation drawn from a zero-truncated Gaussian (mean 3.03e-7 s high /
//! 3.03e-6 s low) served FIFO through an M/G/1 queue. Memory follows
//! §III-B: registers for in-flight blocks must fit in the register file;
//! when they cannot, the round is processed in waves (see `waves_needed`).

use crate::configx::PsProfile;
use crate::net::Mg1Queue;
use crate::sim::SimTime;
use crate::switch::alu;
use crate::switch::memory::{window_blocks, Allocation, MemError, RegisterFile};
use crate::switch::scoreboard::{Mark, Scoreboard};
use crate::util::{BitVec, Rng};

/// Cumulative switch counters surfaced to experiments.
#[derive(Debug, Clone, Default)]
pub struct SwitchStats {
    /// Packets serviced (shadow-shard ops included).
    pub packets_processed: u64,
    /// One aggregation op per serviced packet — the paper's cost unit.
    pub agg_ops: u64,
    /// Duplicates the scoreboard refused to aggregate.
    pub duplicates_dropped: u64,
    /// Accumulator lanes that saturated i32.
    pub overflow_lanes: u64,
    /// Extra register waves forced by memory pressure.
    pub waves: u64,
    /// Peak register bytes actually resident (≤ capacity).
    pub peak_mem_used: usize,
    /// Largest register demand seen (may exceed capacity ⇒ waves).
    pub peak_mem_demanded: usize,
}

/// The switch: service-time model + register file + counters.
pub struct ProgrammableSwitch {
    profile: PsProfile,
    queue: Mg1Queue,
    registers: RegisterFile,
    rng: Rng,
    stats: SwitchStats,
}

impl ProgrammableSwitch {
    /// Switch with `profile`'s service model and register capacity.
    pub fn new(profile: PsProfile, seed: u64) -> Self {
        let registers = RegisterFile::new(profile.memory_bytes);
        ProgrammableSwitch {
            profile,
            queue: Mg1Queue::new(),
            registers,
            rng: Rng::new(seed ^ 0x5717c4),
            stats: SwitchStats::default(),
        }
    }

    /// The performance profile this switch runs.
    pub fn profile(&self) -> &PsProfile {
        &self.profile
    }

    /// Serve one packet arriving at `arrival`; returns its departure time
    /// (aggregation applied). Charges exactly one aggregation op.
    pub fn service_packet(&mut self, arrival: SimTime) -> SimTime {
        let service = self
            .rng
            .gaussian_pos(self.profile.agg_mean_s, self.profile.agg_jitter_s);
        self.stats.packets_processed += 1;
        self.stats.agg_ops += 1;
        self.queue.serve(arrival, service)
    }

    /// Account a dropped duplicate (serviced but not aggregated).
    pub fn note_duplicate(&mut self) {
        self.stats.duplicates_dropped += 1;
    }

    /// Charge an aggregation op served on a collaborating shard switch
    /// (multi-PS mode): counts toward system-wide ops without touching
    /// this switch's queue.
    pub fn note_shadow_op(&mut self) {
        self.stats.packets_processed += 1;
        self.stats.agg_ops += 1;
    }

    /// Account saturated accumulator lanes.
    pub fn note_overflow(&mut self, lanes: u64) {
        self.stats.overflow_lanes += lanes;
    }

    /// Account extra register waves a phase needed.
    pub fn note_waves(&mut self, waves: u64) {
        self.stats.waves += waves;
    }

    /// Record a round's register working set: `used` is what fit in the
    /// file (≤ capacity), `demanded` is what the phase would have wanted
    /// without wave-serialisation.
    pub fn note_memory_demand(&mut self, used: usize, demanded: usize) {
        self.stats.peak_mem_used = self.stats.peak_mem_used.max(used.min(self.profile.memory_bytes));
        self.stats.peak_mem_demanded = self.stats.peak_mem_demanded.max(demanded);
    }

    /// The switch's register file (aggregators allocate from it).
    pub fn registers(&mut self) -> &mut RegisterFile {
        &mut self.registers
    }

    /// Peak register bytes ever resident.
    pub fn peak_memory(&self) -> usize {
        self.registers.peak()
    }

    /// Cumulative counters.
    pub fn stats(&self) -> &SwitchStats {
        self.stats_ref()
    }

    fn stats_ref(&self) -> &SwitchStats {
        &self.stats
    }

    /// Mean queueing delay packets saw (excludes service time).
    pub fn mean_queue_wait(&self) -> f64 {
        self.queue.mean_wait()
    }

    /// New round: the aggregation queue idles between rounds.
    pub fn reset_queue(&mut self) {
        self.queue.reset();
    }
}

/// Phase-1 program: vote-counter accumulation + GIA thresholding.
pub struct VoteAggregator {
    d: usize,
    n_clients: usize,
    threshold_a: u16,
    elems_per_block: usize,
    counters: Vec<u16>,
    scoreboard: Scoreboard,
    alloc: Allocation,
}

impl VoteAggregator {
    /// Allocate counters for all `d` dimensions from the register file.
    /// 2 bytes per dimension — phase 1's entire memory footprint.
    pub fn new(
        rf: &mut RegisterFile,
        d: usize,
        n_clients: usize,
        threshold_a: usize,
        elems_per_block: usize,
    ) -> Result<Self, MemError> {
        let alloc = rf.alloc(d * 2)?;
        let n_blocks = d.div_ceil(elems_per_block);
        Ok(VoteAggregator {
            d,
            n_clients,
            threshold_a: threshold_a as u16,
            elems_per_block,
            counters: vec![0u16; d],
            scoreboard: Scoreboard::new(n_blocks, n_clients),
            alloc,
        })
    }

    /// Blocks in this aggregator's space.
    pub fn n_blocks(&self) -> usize {
        self.scoreboard.n_blocks()
    }

    /// Ingest one client's vote packet for `block` (packed LE bits covering
    /// dims [block·epb, min(d, (block+1)·epb))).
    pub fn ingest(&mut self, client: usize, block: usize, payload_bits: &[u8]) -> Mark {
        let mark = self.scoreboard.mark(block, client);
        if mark == Mark::Duplicate {
            return mark;
        }
        let lo = block * self.elems_per_block;
        let hi = (lo + self.elems_per_block).min(self.d);
        alu::add_vote_bits(&mut self.counters[lo..hi], payload_bits);
        mark
    }

    /// True when every block has every client's contribution.
    pub fn all_complete(&self) -> bool {
        self.scoreboard.all_complete()
    }

    /// Threshold the counters into the GIA (requires all blocks complete
    /// unless `partial` semantics are wanted for failure tests).
    pub fn gia(&self) -> BitVec {
        let mut bytes = vec![0u8; self.d.div_ceil(8)];
        alu::threshold_votes(&self.counters, self.threshold_a, &mut bytes);
        BitVec::from_bytes(self.d, &bytes)
    }

    /// Raw vote histogram (used by experiments to study consensus).
    pub fn counters(&self) -> &[u16] {
        &self.counters
    }

    /// Contributing clients per block.
    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Free register memory.
    pub fn release(self, rf: &mut RegisterFile) {
        rf.free(self.alloc);
    }
}

/// Phase-2 / baseline program: aligned integer accumulation.
pub struct UpdateAggregator {
    n_elems: usize,
    elems_per_block: usize,
    acc: Vec<i32>,
    scoreboard: Scoreboard,
    alloc: Allocation,
    overflow_lanes: u64,
}

impl UpdateAggregator {
    /// Allocate `n_elems` i32 accumulators (4 bytes each).
    pub fn new(
        rf: &mut RegisterFile,
        n_elems: usize,
        n_clients: usize,
        elems_per_block: usize,
    ) -> Result<Self, MemError> {
        let alloc = rf.alloc(n_elems * 4)?;
        let n_blocks = n_elems.div_ceil(elems_per_block.max(1)).max(1);
        Ok(UpdateAggregator {
            n_elems,
            elems_per_block,
            acc: vec![0i32; n_elems],
            scoreboard: Scoreboard::new(n_blocks, n_clients),
            alloc,
            overflow_lanes: 0,
        })
    }

    /// Blocks in this aggregator's space.
    pub fn n_blocks(&self) -> usize {
        self.scoreboard.n_blocks()
    }

    /// Ingest one client's update packet for `block`.
    pub fn ingest(&mut self, client: usize, block: usize, payload: &[i32]) -> Mark {
        let mark = self.scoreboard.mark(block, client);
        if mark == Mark::Duplicate {
            return mark;
        }
        let lo = block * self.elems_per_block;
        let hi = (lo + payload.len()).min(self.n_elems);
        self.overflow_lanes += alu::add_i32_sat(&mut self.acc[lo..hi], &payload[..hi - lo]);
        mark
    }

    /// True when every block has every client's contribution.
    pub fn all_complete(&self) -> bool {
        self.scoreboard.all_complete()
    }

    /// The summed integer lanes.
    pub fn aggregate(&self) -> &[i32] {
        &self.acc
    }

    /// Lanes that saturated during accumulation.
    pub fn overflow_lanes(&self) -> u64 {
        self.overflow_lanes
    }

    /// Free register memory.
    pub fn release(self, rf: &mut RegisterFile) {
        rf.free(self.alloc);
    }
}

/// How many sequential waves a phase needs when its register demand
/// exceeds the file: blocks are processed `window` at a time.
pub fn waves_needed(total_blocks: usize, window: usize) -> usize {
    if total_blocks == 0 {
        return 0;
    }
    total_blocks.div_ceil(window.max(1))
}

/// Convenience: advertised window for a block of `block_bytes` registers.
pub fn advertised_window(profile: &PsProfile, block_bytes: usize) -> usize {
    window_blocks(profile.memory_bytes, block_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rf(cap: usize) -> RegisterFile {
        RegisterFile::new(cap)
    }

    #[test]
    fn vote_aggregator_motivation_example() {
        // §III-B worked example: d=5, two clients, top-3 votes each,
        // threshold a=2 ⇒ GIA = 01100.
        let mut reg = rf(1024);
        let mut agg = VoteAggregator::new(&mut reg, 5, 2, 2, 5).unwrap();
        assert_eq!(agg.n_blocks(), 1);
        let c1 = BitVec::from_indices(5, &[0, 1, 2]);
        let c2 = BitVec::from_indices(5, &[1, 2, 3]);
        assert_eq!(agg.ingest(0, 0, &c1.to_bytes()), Mark::Fresh);
        assert_eq!(agg.ingest(1, 0, &c2.to_bytes()), Mark::Completed);
        assert!(agg.all_complete());
        let gia = agg.gia();
        let selected: Vec<usize> = gia.iter_ones().collect();
        assert_eq!(selected, vec![1, 2]);
        agg.release(&mut reg);
        assert_eq!(reg.used(), 0);
    }

    #[test]
    fn vote_aggregator_multi_block() {
        let d = 20;
        let epb = 8; // 8 dims per packet ⇒ 3 blocks
        let mut reg = rf(1024);
        let mut agg = VoteAggregator::new(&mut reg, d, 2, 1, epb).unwrap();
        assert_eq!(agg.n_blocks(), 3);
        let votes = BitVec::from_indices(d, &[0, 7, 8, 15, 16, 19]);
        let bytes = votes.to_bytes();
        for client in 0..2 {
            for block in 0..3 {
                let lo = block * epb;
                let hi = (lo + epb).min(d);
                let chunk = BitVec::from_indices(
                    hi - lo,
                    &votes
                        .iter_ones()
                        .filter(|&i| i >= lo && i < hi)
                        .map(|i| i - lo)
                        .collect::<Vec<_>>(),
                );
                agg.ingest(client, block, &chunk.to_bytes());
            }
        }
        let _ = bytes;
        assert!(agg.all_complete());
        let gia = agg.gia();
        assert_eq!(gia.iter_ones().collect::<Vec<_>>(), vec![0, 7, 8, 15, 16, 19]);
        agg.release(&mut reg);
    }

    #[test]
    fn vote_memory_exhaustion() {
        let mut reg = rf(10); // room for 5 counters only
        assert!(VoteAggregator::new(&mut reg, 6, 2, 1, 8).is_err());
        assert!(VoteAggregator::new(&mut reg, 5, 2, 1, 8).is_ok());
    }

    #[test]
    fn update_aggregator_sums_aligned_blocks() {
        let mut reg = rf(1024);
        let mut agg = UpdateAggregator::new(&mut reg, 6, 2, 4).unwrap();
        assert_eq!(agg.n_blocks(), 2);
        agg.ingest(0, 0, &[1, 2, 3, 4]);
        agg.ingest(0, 1, &[5, 6]);
        agg.ingest(1, 0, &[10, 20, 30, 40]);
        agg.ingest(1, 1, &[50, 60]);
        assert!(agg.all_complete());
        assert_eq!(agg.aggregate(), &[11, 22, 33, 44, 55, 66]);
        agg.release(&mut reg);
    }

    #[test]
    fn update_duplicate_not_double_counted() {
        let mut reg = rf(64);
        let mut agg = UpdateAggregator::new(&mut reg, 2, 2, 2).unwrap();
        agg.ingest(0, 0, &[1, 1]);
        assert_eq!(agg.ingest(0, 0, &[1, 1]), Mark::Duplicate);
        agg.ingest(1, 0, &[1, 1]);
        assert_eq!(agg.aggregate(), &[2, 2]);
        agg.release(&mut reg);
    }

    #[test]
    fn service_times_scale_with_profile() {
        let mut hi = ProgrammableSwitch::new(PsProfile::high(), 1);
        let mut lo = ProgrammableSwitch::new(PsProfile::low(), 1);
        let n = 10_000;
        let mut t_hi = 0.0;
        let mut t_lo = 0.0;
        for i in 0..n {
            let arrival = i as f64 * 1e-9; // back-to-back ⇒ service-bound
            t_hi = hi.service_packet(arrival);
            t_lo = lo.service_packet(arrival);
        }
        // Low-performance switch is ~10× slower end-to-end.
        let ratio = t_lo / t_hi;
        assert!((5.0..20.0).contains(&ratio), "ratio {ratio}");
        assert_eq!(hi.stats().agg_ops, n as u64);
    }

    #[test]
    fn waves_math() {
        assert_eq!(waves_needed(0, 10), 0);
        assert_eq!(waves_needed(10, 10), 1);
        assert_eq!(waves_needed(11, 10), 2);
        assert_eq!(waves_needed(5, 0), 5); // degenerate window clamps to 1
    }
}
