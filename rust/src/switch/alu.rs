//! Integer-only data-plane arithmetic.
//!
//! A PS "can only perform integer arithmetic" (§IV step 3 / [5]); floats
//! never cross the data plane. These are the only two operations FediAC
//! and the baselines need, and both are on the per-packet hot path:
//!
//! * phase 2 / SwitchML / OmniReduce: lane-wise `i32` accumulate;
//! * phase 1: add a packed 0-1 vote array into `u16` vote counters.
//!
//! Saturation is counted, not silently wrapped — overflow on a real
//! switch corrupts the aggregate, so the simulator surfaces it as a stat.

/// Lane-wise saturating i32 accumulate; returns the number of lanes that
/// saturated (data-plane overflow events).
pub fn add_i32_sat(acc: &mut [i32], payload: &[i32]) -> u64 {
    debug_assert_eq!(acc.len(), payload.len());
    let mut overflows = 0;
    for (a, &p) in acc.iter_mut().zip(payload) {
        let (sum, over) = a.overflowing_add(p);
        if over {
            *a = if *a >= 0 { i32::MAX } else { i32::MIN };
            overflows += 1;
        } else {
            *a = sum;
        }
    }
    overflows
}

/// Add a packed little-endian bit payload into `u16` vote counters.
/// `counters[i] += bit(i)` for i in 0..counters.len(). Saturating (a vote
/// count can never legitimately exceed N ≤ 65535 anyway).
pub fn add_vote_bits(counters: &mut [u16], bits: &[u8]) {
    for (i, ctr) in counters.iter_mut().enumerate() {
        let byte = bits[i >> 3];
        let bit = (byte >> (i & 7)) & 1;
        *ctr = ctr.saturating_add(bit as u16);
    }
}

/// Threshold the vote counters into GIA bits (§IV step 2): bit i is set
/// iff counters[i] ≥ a. Writes packed little-endian bytes into `out`.
pub fn threshold_votes(counters: &[u16], a: u16, out: &mut [u8]) {
    debug_assert!(out.len() * 8 >= counters.len());
    out.iter_mut().for_each(|b| *b = 0);
    for (i, &c) in counters.iter().enumerate() {
        if c >= a {
            out[i >> 3] |= 1 << (i & 7);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i32_accumulate() {
        let mut acc = vec![1, -2, 3];
        let over = add_i32_sat(&mut acc, &[10, 20, -30]);
        assert_eq!(acc, vec![11, 18, -27]);
        assert_eq!(over, 0);
    }

    #[test]
    fn i32_saturates_and_counts() {
        let mut acc = vec![i32::MAX - 1, i32::MIN + 1];
        let over = add_i32_sat(&mut acc, &[5, -5]);
        assert_eq!(acc, vec![i32::MAX, i32::MIN]);
        assert_eq!(over, 2);
    }

    #[test]
    fn vote_bits_accumulate() {
        let mut ctr = vec![0u16; 10];
        // bits 0,1,2 set in first byte; bit 9 set in second byte.
        let payload = [0b0000_0111u8, 0b0000_0010];
        add_vote_bits(&mut ctr, &payload);
        add_vote_bits(&mut ctr, &payload);
        assert_eq!(ctr, vec![2, 2, 2, 0, 0, 0, 0, 0, 0, 2]);
    }

    #[test]
    fn threshold_matches_paper_example() {
        // §III-B: votes 11100 + 01110 = 12210, threshold 2 ⇒ GIA 01100.
        let mut ctr = vec![0u16; 5];
        add_vote_bits(&mut ctr, &[0b0000_0111]); // client 1: dims 0,1,2
        add_vote_bits(&mut ctr, &[0b0000_1110]); // client 2: dims 1,2,3
        assert_eq!(ctr, vec![1, 2, 2, 1, 0]);
        let mut gia = [0u8; 1];
        threshold_votes(&ctr, 2, &mut gia);
        assert_eq!(gia[0], 0b0000_0110); // dims 1 and 2 selected
    }

    #[test]
    fn threshold_clears_previous_bits() {
        let ctr = vec![5u16, 0, 5];
        let mut out = [0xFFu8];
        threshold_votes(&ctr, 3, &mut out);
        assert_eq!(out[0], 0b0000_0101);
    }
}
