//! Integer-only data-plane arithmetic.
//!
//! A PS "can only perform integer arithmetic" (§IV step 3 / [5]); floats
//! never cross the data plane. These are the only two operations FediAC
//! and the baselines need, and both are on the per-packet hot path:
//!
//! * phase 2 / SwitchML / OmniReduce: lane-wise `i32` accumulate;
//! * phase 1: add a packed 0-1 vote array into `u16` vote counters.
//!
//! Saturation is counted, not silently wrapped — overflow on a real
//! switch corrupts the aggregate, so the simulator surfaces it as a stat.
//!
//! The kernels here are **word-parallel**: vote payloads are consumed 64
//! bits at a time (set-bit iteration via `trailing_zeros`, so a sparse
//! paper-density bitmap costs ~k operations rather than d), thresholding
//! builds one output word per 64 counters, and the i32 accumulate is a
//! fixed-width chunked loop the autovectorizer turns into SIMD lanes.
//! The [`scalar`] module keeps the one-bit/one-lane originals as
//! reference oracles: property tests assert bit-exact agreement
//! (including tail-word and odd-`d` edge cases) and `fediac bench-codec`
//! measures the speedup against them.

/// Lanes per unrolled chunk of the i32 accumulate (wide enough for one
/// AVX2 register; the compiler fuses the fixed-size inner loop).
const I32_CHUNK: usize = 8;

/// Lane-wise saturating i32 accumulate; returns the number of lanes that
/// saturated (data-plane overflow events).
///
/// Branchless: `saturating_add` differs from `wrapping_add` exactly when
/// the addition overflowed (the wrapped value can never equal the
/// saturated one for any `i32` pair), so the overflow count is a compare
/// the vectorizer keeps in-lane instead of a per-element branch.
pub fn add_i32_sat(acc: &mut [i32], payload: &[i32]) -> u64 {
    debug_assert_eq!(acc.len(), payload.len());
    let mut overflows = 0u64;
    let split = acc.len() - acc.len() % I32_CHUNK;
    let (acc_body, acc_tail) = acc.split_at_mut(split);
    let (pay_body, pay_tail) = payload.split_at(split);
    for (ac, pc) in acc_body.chunks_exact_mut(I32_CHUNK).zip(pay_body.chunks_exact(I32_CHUNK)) {
        let mut over = 0u64;
        for (a, &p) in ac.iter_mut().zip(pc) {
            let sat = a.saturating_add(p);
            over += (sat != a.wrapping_add(p)) as u64;
            *a = sat;
        }
        overflows += over;
    }
    for (a, &p) in acc_tail.iter_mut().zip(pay_tail) {
        let sat = a.saturating_add(p);
        overflows += (sat != a.wrapping_add(p)) as u64;
        *a = sat;
    }
    overflows
}

/// Add a packed little-endian bit payload into `u16` vote counters.
/// `counters[i] += bit(i)` for i in 0..counters.len(). Saturating (a vote
/// count can never legitimately exceed N ≤ 65535 anyway).
///
/// Word-parallel: the payload is loaded 64 bits at a time and only the
/// *set* bits are visited (`trailing_zeros` + clear-lowest-bit), so the
/// cost is proportional to the vote count, not the dimension — the
/// paper's 5% density makes this ~20× fewer counter touches than the
/// per-bit walk in [`scalar::add_vote_bits`].
pub fn add_vote_bits(counters: &mut [u16], bits: &[u8]) {
    let n = counters.len();
    debug_assert!(bits.len() * 8 >= n, "short vote payload");
    for (wi, chunk) in bits.chunks(8).enumerate() {
        let base = wi * 64;
        if base >= n {
            break;
        }
        let mut buf = [0u8; 8];
        buf[..chunk.len()].copy_from_slice(chunk);
        let mut w = u64::from_le_bytes(buf);
        let lanes = (n - base).min(64);
        if lanes < 64 {
            // Tail word: bits past the counter range are padding, not votes.
            w &= (1u64 << lanes) - 1;
        }
        let ctr = &mut counters[base..base + lanes];
        while w != 0 {
            let b = w.trailing_zeros() as usize;
            ctr[b] = ctr[b].saturating_add(1);
            w &= w - 1;
        }
    }
}

/// Threshold the vote counters into GIA bits (§IV step 2): bit i is set
/// iff counters[i] ≥ a. Writes packed little-endian bytes into `out`.
///
/// Word-parallel: one 64-bit output word is packed per 64 counters
/// (branchless `(c ≥ a)` fan-in) and stored in a single little-endian
/// write, instead of a read-modify-write per bit.
pub fn threshold_votes(counters: &[u16], a: u16, out: &mut [u8]) {
    debug_assert!(out.len() * 8 >= counters.len());
    out.iter_mut().for_each(|b| *b = 0);
    for (wi, lanes) in counters.chunks(64).enumerate() {
        let mut w = 0u64;
        for (i, &c) in lanes.iter().enumerate() {
            w |= ((c >= a) as u64) << i;
        }
        let lo = wi * 8;
        let take = (out.len() - lo).min(8);
        out[lo..lo + take].copy_from_slice(&w.to_le_bytes()[..take]);
    }
}

/// One-bit / one-lane reference implementations of the data-plane
/// kernels — the exact pre-optimisation code paths, kept as oracles.
/// Property tests assert the word-parallel kernels match them bit for
/// bit, and `fediac bench-codec` measures the word-parallel speedup
/// against them in the same run.
pub mod scalar {
    /// Reference [`super::add_i32_sat`]: one lane at a time, branching
    /// on `overflowing_add`.
    pub fn add_i32_sat(acc: &mut [i32], payload: &[i32]) -> u64 {
        debug_assert_eq!(acc.len(), payload.len());
        let mut overflows = 0;
        for (a, &p) in acc.iter_mut().zip(payload) {
            let (sum, over) = a.overflowing_add(p);
            if over {
                *a = if *a >= 0 { i32::MAX } else { i32::MIN };
                overflows += 1;
            } else {
                *a = sum;
            }
        }
        overflows
    }

    /// Reference [`super::add_vote_bits`]: one bit extracted per counter,
    /// with a byte load and shift each.
    pub fn add_vote_bits(counters: &mut [u16], bits: &[u8]) {
        for (i, ctr) in counters.iter_mut().enumerate() {
            let byte = bits[i >> 3];
            let bit = (byte >> (i & 7)) & 1;
            *ctr = ctr.saturating_add(bit as u16);
        }
    }

    /// Reference [`super::threshold_votes`]: one read-modify-write per
    /// set bit.
    pub fn threshold_votes(counters: &[u16], a: u16, out: &mut [u8]) {
        debug_assert!(out.len() * 8 >= counters.len());
        out.iter_mut().for_each(|b| *b = 0);
        for (i, &c) in counters.iter().enumerate() {
            if c >= a {
                out[i >> 3] |= 1 << (i & 7);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, BitVec};

    #[test]
    fn i32_accumulate() {
        let mut acc = vec![1, -2, 3];
        let over = add_i32_sat(&mut acc, &[10, 20, -30]);
        assert_eq!(acc, vec![11, 18, -27]);
        assert_eq!(over, 0);
    }

    #[test]
    fn i32_saturates_and_counts() {
        let mut acc = vec![i32::MAX - 1, i32::MIN + 1];
        let over = add_i32_sat(&mut acc, &[5, -5]);
        assert_eq!(acc, vec![i32::MAX, i32::MIN]);
        assert_eq!(over, 2);
    }

    #[test]
    fn vote_bits_accumulate() {
        let mut ctr = vec![0u16; 10];
        // bits 0,1,2 set in first byte; bit 9 set in second byte.
        let payload = [0b0000_0111u8, 0b0000_0010];
        add_vote_bits(&mut ctr, &payload);
        add_vote_bits(&mut ctr, &payload);
        assert_eq!(ctr, vec![2, 2, 2, 0, 0, 0, 0, 0, 0, 2]);
    }

    #[test]
    fn threshold_matches_paper_example() {
        // §III-B: votes 11100 + 01110 = 12210, threshold 2 ⇒ GIA 01100.
        let mut ctr = vec![0u16; 5];
        add_vote_bits(&mut ctr, &[0b0000_0111]); // client 1: dims 0,1,2
        add_vote_bits(&mut ctr, &[0b0000_1110]); // client 2: dims 1,2,3
        assert_eq!(ctr, vec![1, 2, 2, 1, 0]);
        let mut gia = [0u8; 1];
        threshold_votes(&ctr, 2, &mut gia);
        assert_eq!(gia[0], 0b0000_0110); // dims 1 and 2 selected
    }

    #[test]
    fn threshold_clears_previous_bits() {
        let ctr = vec![5u16, 0, 5];
        let mut out = [0xFFu8];
        threshold_votes(&ctr, 3, &mut out);
        assert_eq!(out[0], 0b0000_0101);
    }

    #[test]
    fn vote_bits_tail_padding_is_ignored() {
        // Padding bits past the counter range (here bits 3..8 of the
        // payload byte) must not corrupt adjacent memory or counters.
        let mut ctr = vec![0u16; 3];
        add_vote_bits(&mut ctr, &[0xFF]);
        assert_eq!(ctr, vec![1, 1, 1]);
    }

    #[test]
    fn vote_bits_saturate_at_u16_max() {
        let mut word = vec![u16::MAX; 1];
        add_vote_bits(&mut word, &[0x01]);
        assert_eq!(word[0], u16::MAX);
        let mut word = vec![u16::MAX; 1];
        scalar::add_vote_bits(&mut word, &[0x01]);
        assert_eq!(word[0], u16::MAX);
    }

    /// Seeded random payloads across boundary dimensions: the
    /// word-parallel kernels must match the scalar oracles bit for bit,
    /// including tail words and odd `d`.
    #[test]
    fn word_parallel_matches_scalar_oracles() {
        prop::check("alu_word_vs_scalar", prop::default_cases(), |rng| {
            let d = prop::gen_dim(rng);
            // Random-density payload (dense and sparse both covered).
            let density = rng.f64();
            let mut bv = BitVec::zeros(d);
            for i in 0..d {
                if rng.f64() < density {
                    bv.set(i, true);
                }
            }
            let payload = bv.to_bytes();

            // Vote absorption, on counters pre-seeded near saturation
            // sometimes so the saturating path is exercised too.
            let seed_high = rng.f64() < 0.25;
            let mut fast = vec![if seed_high { u16::MAX - 1 } else { 0 }; d];
            let mut slow = fast.clone();
            add_vote_bits(&mut fast, &payload);
            scalar::add_vote_bits(&mut slow, &payload);
            crate::prop_assert!(fast == slow, "add_vote_bits diverged at d={d}");
            // Repeat-absorb to push counts up.
            for _ in 0..3 {
                add_vote_bits(&mut fast, &payload);
                scalar::add_vote_bits(&mut slow, &payload);
            }
            crate::prop_assert!(fast == slow, "repeated add_vote_bits diverged at d={d}");

            // Thresholding of the accumulated counters.
            let a = 1 + rng.below(4) as u16;
            let mut out_fast = vec![0xAAu8; d.div_ceil(8)];
            let mut out_slow = vec![0x55u8; d.div_ceil(8)];
            threshold_votes(&fast, a, &mut out_fast);
            scalar::threshold_votes(&slow, a, &mut out_slow);
            crate::prop_assert!(out_fast == out_slow, "threshold_votes diverged at d={d} a={a}");

            // i32 accumulate with values spanning the saturation range.
            let mut acc_fast: Vec<i32> = (0..d)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        if rng.f64() < 0.5 { i32::MAX - 3 } else { i32::MIN + 3 }
                    } else {
                        rng.next_u32() as i32 >> 8
                    }
                })
                .collect();
            let mut acc_slow = acc_fast.clone();
            let lanes: Vec<i32> = (0..d)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        if rng.f64() < 0.5 { i32::MAX } else { i32::MIN }
                    } else {
                        rng.next_u32() as i32 >> 8
                    }
                })
                .collect();
            let over_fast = add_i32_sat(&mut acc_fast, &lanes);
            let over_slow = scalar::add_i32_sat(&mut acc_slow, &lanes);
            crate::prop_assert!(acc_fast == acc_slow, "add_i32_sat lanes diverged at d={d}");
            crate::prop_assert!(
                over_fast == over_slow,
                "add_i32_sat overflow count diverged at d={d}: {over_fast} vs {over_slow}"
            );
            Ok(())
        });
    }
}
