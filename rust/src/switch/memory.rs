//! Switch register-memory model.
//!
//! The whole paper exists because "the memory space of a PS is very
//! limited" (§III-B: ~1 MB allocatable to FL on a Tofino-class switch).
//! Aggregation state must fit in this register file; when a round's
//! working set exceeds it, the data plane must process the index space in
//! waves, multiplying aggregation latency. This module does the strict
//! byte accounting that drives that behaviour.

/// Byte-accounted register file.
#[derive(Debug, Clone)]
pub struct RegisterFile {
    capacity: usize,
    used: usize,
    peak: usize,
}

/// Handle for an allocation (freed explicitly; Drop-free for determinism).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    /// Size of the reservation being held.
    pub bytes: usize,
}

/// Register allocation failures.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum MemError {
    /// The request does not fit the remaining register memory.
    #[error("register file exhausted: requested {requested} B, free {free} B of {capacity} B")]
    Exhausted { requested: usize, free: usize, capacity: usize },
}

impl RegisterFile {
    /// Empty file of `capacity` bytes.
    pub fn new(capacity: usize) -> Self {
        RegisterFile { capacity, used: 0, peak: 0 }
    }

    /// Reserve `bytes`; fails when the request does not fit.
    pub fn alloc(&mut self, bytes: usize) -> Result<Allocation, MemError> {
        let free = self.capacity - self.used;
        if bytes > free {
            return Err(MemError::Exhausted { requested: bytes, free, capacity: self.capacity });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(Allocation { bytes })
    }

    /// Release a previous allocation.
    pub fn free(&mut self, alloc: Allocation) {
        debug_assert!(alloc.bytes <= self.used, "double free");
        self.used -= alloc.bytes.min(self.used);
    }

    /// Total register bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes currently reserved.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Bytes still available.
    pub fn free_bytes(&self) -> usize {
        self.capacity - self.used
    }

    /// High-water mark across the lifetime of this register file.
    pub fn peak(&self) -> usize {
        self.peak
    }
}

/// How many whole aggregation blocks of `block_bytes` fit in `capacity`.
/// This is the switch's advertised in-flight window: clients may not have
/// packets outstanding beyond it (flow control, SwitchML-style slots).
pub fn window_blocks(capacity: usize, block_bytes: usize) -> usize {
    if block_bytes == 0 {
        return usize::MAX;
    }
    (capacity / block_bytes).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_accounting() {
        let mut rf = RegisterFile::new(1000);
        let a = rf.alloc(400).unwrap();
        let b = rf.alloc(600).unwrap();
        assert_eq!(rf.used(), 1000);
        assert_eq!(rf.free_bytes(), 0);
        assert_eq!(
            rf.alloc(1),
            Err(MemError::Exhausted { requested: 1, free: 0, capacity: 1000 })
        );
        rf.free(a);
        assert_eq!(rf.free_bytes(), 400);
        rf.free(b);
        assert_eq!(rf.used(), 0);
        assert_eq!(rf.peak(), 1000);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut rf = RegisterFile::new(100);
        let a = rf.alloc(70).unwrap();
        rf.free(a);
        let _ = rf.alloc(30).unwrap();
        assert_eq!(rf.peak(), 70);
    }

    #[test]
    fn window_blocks_examples() {
        // 1 MiB of registers, 1438-byte payload blocks of 32-bit ints:
        // each block needs 1438 bytes of accumulators.
        assert_eq!(window_blocks(1 << 20, 1438), (1 << 20) / 1438);
        assert_eq!(window_blocks(100, 1000), 1); // always at least one
        assert_eq!(window_blocks(100, 0), usize::MAX);
    }
}
