//! Time-ordered event queue with deterministic tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated wall-clock time in seconds.
pub type SimTime = f64;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; ties broken by insertion order so the
        // simulation is deterministic regardless of float coincidences.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue keyed by simulated time.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Schedule `payload` at absolute time `at` (must not precede `now`).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        debug_assert!(
            at >= self.now - 1e-12,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Entry { time: at.max(self.now), seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule relative to the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        let at = self.now + delay.max(0.0);
        self.schedule(at, payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    /// Current simulated time (timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Scheduled events outstanding.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        let (t2, _) = q.pop().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(10.0, 0);
        q.pop();
        q.schedule_in(2.5, 1);
        let (t, _) = q.pop().unwrap();
        assert!((t - 12.5).abs() < 1e-12);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
