//! Discrete-event simulation engine.
//!
//! The paper evaluates FediAC on a simulated testbed (§V-A2): clients
//! upload packets as Poisson processes, the PS serves them through an
//! M/G/1 queue, and figures plot accuracy against *simulated* wall-clock.
//! This module provides the deterministic event core those models run on.

pub mod event;

pub use event::{EventQueue, SimTime};
