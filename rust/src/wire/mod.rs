//! Binary wire format for the networked FediAC aggregation service.
//!
//! The simulator models packets as in-process descriptors
//! ([`crate::net::packet::Packet`] carries sizes, never bytes); this module
//! is the real thing: a fixed 40-byte checksummed header followed by a
//! phase-specific payload, one frame per UDP datagram.
//!
//! * `Vote` frames carry packed 0-1 vote bitmaps (one bit per model
//!   dimension, the [`crate::util::BitVec`] wire layout);
//! * `Update` frames carry quantised little-endian i32 lanes in GIA order
//!   (the [`crate::compress::quantize`] integers);
//! * `Gia` broadcast frames carry the Golomb–Rice-coded GIA
//!   ([`crate::compress::golomb`]) split into MTU-sized chunks;
//! * `Aggregate` broadcast frames carry the summed i32 lanes.
//!
//! Decoding is strict: truncation, a bad magic, an unknown version/kind, a
//! length mismatch or a checksum failure each produce a distinct
//! [`WireError`]; a frame that decodes is internally consistent. Decoding
//! is also zero-copy — [`frame::Frame`] borrows the payload from the
//! receive buffer, and lane readers iterate the raw bytes.

pub mod frame;
pub mod payload;
pub mod pool;
pub mod shard;

pub use frame::{
    crc32, decode_frame, encode_frame, encode_frame_into, peek_route, Frame, Header, WireKind,
    DEFAULT_PAYLOAD_BUDGET, HEADER_LEN, MAGIC, MAX_DATAGRAM, MAX_WIRE_PAYLOAD, VERSION,
};
pub use payload::{
    byte_chunk_bounds, byte_chunks, decode_lanes, encode_lanes, encode_lanes_into, lanes_iter,
    update_chunk_bounds, update_chunks, vote_chunk_bounds, vote_chunks, ChunkAssembler, JobSpec,
};
pub use pool::FrameScratch;
pub use shard::{ShardLayout, ShardPlan, MAX_SHARDS};

/// Strict decode errors — every way a datagram can be malformed.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum WireError {
    /// Buffer shorter than the header (or its declared payload).
    #[error("truncated frame: need {needed} bytes, got {got}")]
    Truncated { needed: usize, got: usize },
    /// First four bytes are not the protocol magic.
    #[error("bad magic {0:#010x}")]
    BadMagic(u32),
    /// Version byte this implementation does not speak.
    #[error("unsupported version {0}")]
    BadVersion(u8),
    /// Unknown kind discriminant.
    #[error("unknown frame kind {0}")]
    BadKind(u8),
    /// Datagram length disagrees with the declared payload length.
    #[error("declared payload length {declared} != actual {got}")]
    LengthMismatch { declared: usize, got: usize },
    /// CRC-32 over header + payload failed.
    #[error("checksum mismatch: header says {stored:#010x}, computed {computed:#010x}")]
    ChecksumMismatch { stored: u32, computed: u32 },
    /// Frame decoded but its payload violates the phase codec.
    #[error("malformed payload: {0}")]
    BadPayload(&'static str),
}
