//! Fixed-header frame codec: encode to a datagram, decode zero-copy.
//!
//! Layout (all little-endian):
//!
//! ```text
//! off len field        notes
//!   0   4 magic        0x46444143 ("CADF" on the wire)
//!   4   1 version      1
//!   5   1 kind         WireKind discriminant
//!   6   2 client       sender id (uplink); 0xFFFF on broadcast downlink
//!   8   4 job          multi-tenant job id
//!  12   4 round        global FL iteration
//!  16   4 block        aggregation slot / chunk index within the phase
//!  20   4 n_blocks     total blocks in this phase stream (reassembly)
//!  24   4 elems        logical elements in THIS frame (bits / lanes / bytes)
//!  28   4 aux          phase-specific scalar (f32 bits or a count)
//!  32   4 payload_len  bytes following the header
//!  36   4 checksum     CRC-32 over bytes [0,36) + payload
//! ```
//!
//! `aux` semantics per kind: `Vote` → f32 bits of the client's local
//! max-|U| (the PS folds these with max, §IV's m); `Gia` → f32 bits of the
//! global max; `Update` → f32 bits of the scale factor f (server-side
//! sanity only); `Aggregate` → total lane count k_S; `JoinAck` → status
//! code; `Poll` → the `WireKind` being polled.

use crate::net::packet::Phase;
use crate::wire::WireError;

/// Frame magic ("FDAC" as a little-endian u32 constant).
pub const MAGIC: u32 = 0x4644_4143;
/// Wire protocol version.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 40;
/// Default payload budget per datagram: header + payload + IP/UDP overhead
/// stays under a 1500-byte MTU, and the budget is a multiple of 4 so i32
/// lanes pack without padding.
pub const DEFAULT_PAYLOAD_BUDGET: usize = 1408;
/// Largest UDP payload an IPv4 datagram can carry (65535 minus the 20-byte
/// IP and 8-byte UDP headers) — the hard ceiling on any frame's wire size,
/// whatever `payload_budget` a spec declares. Every receive buffer in the
/// daemon and the client driver is sized from this one constant so no
/// legitimate frame can ever be silently truncated by a short `recv`.
pub const MAX_DATAGRAM: usize = 65_507;
/// Largest frame payload that can actually transit the wire
/// ([`MAX_DATAGRAM`] minus the fixed header, rounded down to the 4-byte
/// lane alignment `JobSpec` requires of payload budgets).
pub const MAX_WIRE_PAYLOAD: usize = (MAX_DATAGRAM - HEADER_LEN) & !3;

/// Message kind carried in the header.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum WireKind {
    /// Client → server: job registration (payload = [`super::JobSpec`]).
    Join = 1,
    /// Server → client: Join outcome (`aux` = status code).
    JoinAck = 2,
    /// Client → server: packed vote bitmap block (phase 1).
    Vote = 3,
    /// Server → clients: Golomb-coded GIA chunk (phase 1 result).
    Gia = 4,
    /// Client → server: quantised i32 lanes block (phase 2).
    Update = 5,
    /// Server → clients: aggregated i32 lanes chunk (phase 2 result).
    Aggregate = 6,
    /// Client → server: ask for a phase result (`aux` = polled kind).
    Poll = 7,
    /// Server → client: polled phase not complete yet.
    NotReady = 8,
}

impl WireKind {
    /// Parse the header discriminant; `None` for unknown kinds.
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => WireKind::Join,
            2 => WireKind::JoinAck,
            3 => WireKind::Vote,
            4 => WireKind::Gia,
            5 => WireKind::Update,
            6 => WireKind::Aggregate,
            7 => WireKind::Poll,
            8 => WireKind::NotReady,
            _ => return None,
        })
    }

    /// Map the data-carrying kinds onto the simulator's packet phases.
    pub fn sim_phase(self) -> Option<Phase> {
        match self {
            WireKind::Vote => Some(Phase::Vote),
            WireKind::Update => Some(Phase::Update),
            WireKind::Gia | WireKind::Aggregate => Some(Phase::Broadcast),
            _ => None,
        }
    }
}

/// Decoded fixed header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Frame kind.
    pub kind: WireKind,
    /// Sender's client id (uplink); `0xFFFF` on broadcast downlink.
    pub client: u16,
    /// Multi-tenant job id.
    pub job: u32,
    /// Global FL iteration.
    pub round: u32,
    /// Chunk index within the phase stream.
    pub block: u32,
    /// Total chunks in the phase stream (reassembly).
    pub n_blocks: u32,
    /// Logical elements in THIS frame (bits / lanes / bytes).
    pub elems: u32,
    /// Phase-specific scalar (see the module docs).
    pub aux: u32,
}

impl Header {
    /// Minimal constructor for control frames (no block structure).
    pub fn control(kind: WireKind, job: u32, client: u16, round: u32, aux: u32) -> Self {
        Header { kind, client, job, round, block: 0, n_blocks: 0, elems: 0, aux }
    }
}

/// A decoded frame borrowing its payload from the receive buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Frame<'a> {
    /// The validated fixed header.
    pub header: Header,
    /// Payload bytes, borrowed from the receive buffer.
    pub payload: &'a [u8],
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) over the concatenation of `parts`.
pub fn crc32(parts: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for part in parts {
        for &b in *part {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

#[inline]
fn u32_at(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

#[inline]
fn u16_at(buf: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(buf[off..off + 2].try_into().unwrap())
}

/// Encode one frame into a fresh datagram buffer.
pub fn encode_frame(h: &Header, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_frame_into(&mut buf, h, payload);
    buf
}

/// Encode one frame into a reused buffer (cleared first) — the
/// allocation-free twin of [`encode_frame`] the server's frame pool and
/// the client driver emit through. Identical bytes by construction.
pub fn encode_frame_into(buf: &mut Vec<u8>, h: &Header, payload: &[u8]) {
    buf.clear();
    buf.reserve(HEADER_LEN + payload.len());
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.push(VERSION);
    buf.push(h.kind as u8);
    buf.extend_from_slice(&h.client.to_le_bytes());
    buf.extend_from_slice(&h.job.to_le_bytes());
    buf.extend_from_slice(&h.round.to_le_bytes());
    buf.extend_from_slice(&h.block.to_le_bytes());
    buf.extend_from_slice(&h.n_blocks.to_le_bytes());
    buf.extend_from_slice(&h.elems.to_le_bytes());
    buf.extend_from_slice(&h.aux.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let crc = crc32(&[&buf[..], payload]);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf.extend_from_slice(payload);
}

/// Strict zero-copy decode of one datagram.
pub fn decode_frame(buf: &[u8]) -> Result<Frame<'_>, WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated { needed: HEADER_LEN, got: buf.len() });
    }
    let magic = u32_at(buf, 0);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if buf[4] != VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let kind = WireKind::from_u8(buf[5]).ok_or(WireError::BadKind(buf[5]))?;
    let payload_len = u32_at(buf, 32) as usize;
    if buf.len() < HEADER_LEN + payload_len {
        return Err(WireError::Truncated { needed: HEADER_LEN + payload_len, got: buf.len() });
    }
    if buf.len() != HEADER_LEN + payload_len {
        return Err(WireError::LengthMismatch {
            declared: payload_len,
            got: buf.len() - HEADER_LEN,
        });
    }
    let stored = u32_at(buf, 36);
    let computed = crc32(&[&buf[..36], &buf[HEADER_LEN..]]);
    if stored != computed {
        return Err(WireError::ChecksumMismatch { stored, computed });
    }
    Ok(Frame {
        header: Header {
            kind,
            client: u16_at(buf, 6),
            job: u32_at(buf, 8),
            round: u32_at(buf, 12),
            block: u32_at(buf, 16),
            n_blocks: u32_at(buf, 20),
            elems: u32_at(buf, 24),
            aux: u32_at(buf, 28),
        },
        payload: &buf[HEADER_LEN..],
    })
}

/// Cheap routing peek for the server's dispatch loop: validates only the
/// parts needed to pick a job worker (magic, version, length) and leaves
/// checksum verification to the worker's full decode.
pub fn peek_route(buf: &[u8]) -> Option<(u32, WireKind)> {
    if buf.len() < HEADER_LEN || u32_at(buf, 0) != MAGIC || buf[4] != VERSION {
        return None;
    }
    let kind = WireKind::from_u8(buf[5])?;
    Some((u32_at(buf, 8), kind))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> Header {
        Header {
            kind: WireKind::Update,
            client: 3,
            job: 42,
            round: 7,
            block: 11,
            n_blocks: 12,
            elems: 96,
            aux: 0xDEAD_BEEF,
        }
    }

    #[test]
    fn encode_decode_identity() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let buf = encode_frame(&header(), &payload);
        assert_eq!(buf.len(), HEADER_LEN + payload.len());
        let frame = decode_frame(&buf).unwrap();
        assert_eq!(frame.header, header());
        assert_eq!(frame.payload, &payload[..]);
    }

    #[test]
    fn empty_payload_ok() {
        let buf = encode_frame(&Header::control(WireKind::Poll, 1, 0, 0, 4), &[]);
        let frame = decode_frame(&buf).unwrap();
        assert_eq!(frame.header.kind, WireKind::Poll);
        assert!(frame.payload.is_empty());
    }

    #[test]
    fn truncation_detected() {
        let buf = encode_frame(&header(), &[1, 2, 3, 4]);
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN, buf.len() - 1] {
            let err = decode_frame(&buf[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn bad_magic_and_version() {
        let mut buf = encode_frame(&header(), &[]);
        buf[0] ^= 0xFF;
        assert!(matches!(decode_frame(&buf), Err(WireError::BadMagic(_))));
        let mut buf = encode_frame(&header(), &[]);
        buf[4] = 9;
        assert_eq!(decode_frame(&buf), Err(WireError::BadVersion(9)));
    }

    #[test]
    fn checksum_catches_any_flip() {
        let buf = encode_frame(&header(), &[7; 33]);
        for i in (0..buf.len()).step_by(5) {
            if (32..36).contains(&i) {
                continue; // payload_len flips become length errors instead
            }
            let mut bad = buf.clone();
            bad[i] ^= 0x10;
            let err = decode_frame(&bad).unwrap_err();
            assert!(
                matches!(
                    err,
                    WireError::ChecksumMismatch { .. }
                        | WireError::BadMagic(_)
                        | WireError::BadVersion(_)
                        | WireError::BadKind(_)
                ),
                "byte {i}: {err:?}"
            );
        }
    }

    #[test]
    fn length_mismatch_detected() {
        let mut buf = encode_frame(&header(), &[1, 2, 3, 4]);
        buf.push(0); // trailing garbage
        assert!(matches!(
            decode_frame(&buf),
            Err(WireError::LengthMismatch { declared: 4, got: 5 })
        ));
    }

    #[test]
    fn peek_matches_full_decode() {
        let buf = encode_frame(&header(), &[9; 10]);
        assert_eq!(peek_route(&buf), Some((42, WireKind::Update)));
        assert_eq!(peek_route(&buf[..10]), None);
    }

    #[test]
    fn sim_phase_mapping() {
        assert_eq!(WireKind::Vote.sim_phase(), Some(Phase::Vote));
        assert_eq!(WireKind::Update.sim_phase(), Some(Phase::Update));
        assert_eq!(WireKind::Gia.sim_phase(), Some(Phase::Broadcast));
        assert_eq!(WireKind::Aggregate.sim_phase(), Some(Phase::Broadcast));
        assert_eq!(WireKind::Join.sim_phase(), None);
    }

    #[test]
    fn encode_into_reused_buffer_is_identical() {
        let mut buf = vec![0xEEu8; 300]; // dirty, larger than the frame
        encode_frame_into(&mut buf, &header(), &[1, 2, 3, 4]);
        assert_eq!(buf, encode_frame(&header(), &[1, 2, 3, 4]));
        // Reuse with a different payload leaves no residue.
        encode_frame_into(&mut buf, &header(), &[]);
        assert_eq!(buf, encode_frame(&header(), &[]));
        assert!(decode_frame(&buf).is_ok());
    }

    #[test]
    fn wire_size_constants_are_consistent() {
        // The max payload fits one IPv4 datagram with the header on, and
        // respects the 4-byte lane alignment specs require.
        assert!(HEADER_LEN + MAX_WIRE_PAYLOAD <= MAX_DATAGRAM);
        assert_eq!(MAX_WIRE_PAYLOAD % 4, 0);
        assert!(MAX_WIRE_PAYLOAD <= u16::MAX as usize);
        assert!(DEFAULT_PAYLOAD_BUDGET <= MAX_WIRE_PAYLOAD);
    }

    #[test]
    fn crc_known_vector() {
        // CRC-32("123456789") = 0xCBF43926 — the classic check value.
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
    }
}
