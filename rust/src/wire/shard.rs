//! Shard plane: splitting one job's block space round-robin across N
//! collaborative aggregation servers (the wire realisation of the
//! simulator's `configx::num_switches` / `fl::FlEnv::upload_phase_sharded`
//! multi-PS model, and §VI's collaborative-switches future work).
//!
//! Ownership is defined on *vote blocks*, the unit both phases derive
//! their geometry from: block `b` of the full model belongs to shard
//! `b % n_shards`. A shard therefore serves the sub-model formed by
//! concatenating its owned blocks in ascending block order — every owned
//! block keeps its exact bit width, so the shard's own chunking of the
//! sub-model reproduces the owned blocks one-to-one and the unmodified
//! per-job server state machine ([`crate::server::Job`]) runs each shard:
//! vote ingest, GIA thresholding and update aggregation are restricted to
//! owned blocks by construction.
//!
//! The update phase follows the same ownership: a selected dimension
//! (GIA bit) is uploaded to, and aggregated by, the shard that owns its
//! vote block. Because sub-model dimension order is ascending in global
//! dimension order, per-shard lane streams interleave back into the
//! global GIA-ordered aggregate deterministically ([`ShardLayout`] holds
//! the split/merge maps).
//!
//! The plan itself ([`ShardPlan`]) travels inside
//! [`crate::wire::JobSpec`] so every client of a job registers the same
//! world view with each shard and a server can refuse a client that
//! disagrees (`JOIN_SPEC_MISMATCH`). Single-server deployments carry the
//! trivial plan and are wire-compatible with pre-shard peers (see
//! PROTOCOL.md §8).

use crate::util::BitVec;
use crate::wire::WireError;

/// Hard cap on collaborating shards per job. Generous for the paper's
/// setting (a handful of switches share one index space) while keeping
/// the plan encodable in one byte with room to spare.
pub const MAX_SHARDS: u8 = 16;

/// One shard's identity within a sharded job: how many servers share the
/// block space, and which slice this spec describes. Carried in the two
/// trailing bytes of the [`crate::wire::JobSpec`] wire encoding; a zero
/// `n_shards` byte (all pre-shard encoders) decodes as the single-server
/// plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShardPlan {
    /// Total collaborating servers (1 = unsharded).
    pub n_shards: u8,
    /// This server's slice index in `[0, n_shards)`.
    pub shard_id: u8,
}

impl ShardPlan {
    /// The trivial plan: one server owns every block.
    pub fn single() -> Self {
        ShardPlan { n_shards: 1, shard_id: 0 }
    }

    /// True when the plan is the trivial single-server one.
    pub fn is_single(&self) -> bool {
        self.n_shards <= 1
    }

    /// Structural validity of the plan.
    pub fn validate(&self) -> Result<(), WireError> {
        if self.n_shards == 0 || self.n_shards > MAX_SHARDS {
            return Err(WireError::BadPayload("n_shards must be in [1, 16]"));
        }
        if self.shard_id >= self.n_shards {
            return Err(WireError::BadPayload("shard_id must be < n_shards"));
        }
        Ok(())
    }
}

impl Default for ShardPlan {
    fn default() -> Self {
        ShardPlan::single()
    }
}

/// Deterministic block-ownership map shared by the sharded client driver
/// and the tests: which shard owns which vote block of a `d`-dimension
/// model chunked at `block_bits` dimensions per block, plus the
/// scatter/gather transforms between the global model and each shard's
/// sub-model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardLayout {
    d: usize,
    block_bits: usize,
    n_shards: usize,
}

impl ShardLayout {
    /// Build the layout for a `d`-dimension model with `payload_budget`
    /// bytes per vote frame (the same geometry
    /// [`crate::wire::JobSpec::vote_block_bits`] derives) split over
    /// `n_shards` servers.
    pub fn new(d: usize, payload_budget: usize, n_shards: usize) -> Self {
        ShardLayout {
            d,
            block_bits: payload_budget.max(1) * 8,
            n_shards: n_shards.max(1),
        }
    }

    /// Total vote blocks of the full model.
    pub fn n_blocks(&self) -> usize {
        self.d.div_ceil(self.block_bits).max(1)
    }

    /// Number of shards in the plan.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shard owning vote block `block` (round-robin, mirroring the
    /// simulator's `seq % n_switches` assignment).
    pub fn owner_of_block(&self, block: usize) -> usize {
        block % self.n_shards
    }

    /// Shard owning global model dimension `dim`.
    pub fn owner_of_dim(&self, dim: usize) -> usize {
        (dim / self.block_bits) % self.n_shards
    }

    /// Bit width of global vote block `block` (full `block_bits` except
    /// possibly the last block of the model).
    fn block_width(&self, block: usize) -> usize {
        let lo = block * self.block_bits;
        self.block_bits.min(self.d.saturating_sub(lo))
    }

    /// Sub-model dimension of `shard`: the summed widths of its owned
    /// blocks. Zero when there are more shards than vote blocks — the
    /// sharded client refuses such plans.
    pub fn shard_dims(&self, shard: usize) -> usize {
        (0..self.n_blocks())
            .filter(|&b| self.owner_of_block(b) == shard)
            .map(|b| self.block_width(b))
            .sum()
    }

    /// Scatter a full `d`-bit bitmap into one sub-model bitmap per shard
    /// (owned blocks concatenated in ascending block order).
    pub fn split_bitmap(&self, full: &BitVec) -> Vec<BitVec> {
        assert_eq!(full.len(), self.d, "bitmap length != layout dimension");
        let mut parts: Vec<BitVec> =
            (0..self.n_shards).map(|s| BitVec::zeros(self.shard_dims(s))).collect();
        let mut offsets = vec![0usize; self.n_shards];
        for b in 0..self.n_blocks() {
            let s = self.owner_of_block(b);
            let lo = b * self.block_bits;
            let width = self.block_width(b);
            for i in 0..width {
                if full.get(lo + i) {
                    parts[s].set(offsets[s] + i, true);
                }
            }
            offsets[s] += width;
        }
        parts
    }

    /// Gather per-shard sub-model bitmaps back into the full `d`-bit
    /// bitmap (the inverse of [`Self::split_bitmap`]). Errors when a
    /// part's length disagrees with the layout — a shard served a
    /// different geometry than the plan describes.
    pub fn merge_bitmaps(&self, parts: &[BitVec]) -> Result<BitVec, WireError> {
        if parts.len() != self.n_shards {
            return Err(WireError::BadPayload("shard bitmap count != n_shards"));
        }
        for (s, p) in parts.iter().enumerate() {
            if p.len() != self.shard_dims(s) {
                return Err(WireError::BadPayload("shard bitmap length != owned dims"));
            }
        }
        let mut full = BitVec::zeros(self.d);
        let mut offsets = vec![0usize; self.n_shards];
        for b in 0..self.n_blocks() {
            let s = self.owner_of_block(b);
            let lo = b * self.block_bits;
            let width = self.block_width(b);
            for i in 0..width {
                if parts[s].get(offsets[s] + i) {
                    full.set(lo + i, true);
                }
            }
            offsets[s] += width;
        }
        Ok(full)
    }

    /// Partition the GIA's selected dimensions by owning shard. Each
    /// shard's list is ascending in global dimension order — which is
    /// also that shard's sub-model (upload) order, because owned blocks
    /// concatenate in ascending block order.
    pub fn split_selected(&self, gia: &BitVec) -> Vec<Vec<usize>> {
        assert_eq!(gia.len(), self.d, "GIA length != layout dimension");
        let mut parts: Vec<Vec<usize>> = vec![Vec::new(); self.n_shards];
        for g in gia.iter_ones() {
            parts[self.owner_of_dim(g)].push(g);
        }
        parts
    }

    /// Interleave per-shard aggregate lanes back into global GIA order:
    /// walk the selected dimensions ascending and take the next lane from
    /// each dimension's owner. Errors when a shard returned a lane count
    /// different from its owned selection.
    pub fn merge_lanes(&self, gia: &BitVec, parts: &[Vec<i32>]) -> Result<Vec<i32>, WireError> {
        if parts.len() != self.n_shards {
            return Err(WireError::BadPayload("shard lane-set count != n_shards"));
        }
        let mut cursors = vec![0usize; self.n_shards];
        let mut out = Vec::with_capacity(gia.count_ones());
        for g in gia.iter_ones() {
            let s = self.owner_of_dim(g);
            let Some(&lane) = parts[s].get(cursors[s]) else {
                return Err(WireError::BadPayload("shard aggregate shorter than its GIA slice"));
            };
            cursors[s] += 1;
            out.push(lane);
        }
        for (s, &used) in cursors.iter().enumerate() {
            if used != parts[s].len() {
                return Err(WireError::BadPayload("shard aggregate longer than its GIA slice"));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_validation() {
        assert!(ShardPlan::single().validate().is_ok());
        assert!(ShardPlan { n_shards: 4, shard_id: 3 }.validate().is_ok());
        assert!(ShardPlan { n_shards: 0, shard_id: 0 }.validate().is_err());
        assert!(ShardPlan { n_shards: 17, shard_id: 0 }.validate().is_err());
        assert!(ShardPlan { n_shards: 2, shard_id: 2 }.validate().is_err());
        assert!(ShardPlan::single().is_single());
        assert!(!ShardPlan { n_shards: 2, shard_id: 0 }.is_single());
    }

    #[test]
    fn ownership_is_round_robin_and_covers_the_model() {
        // d = 100 at budget 8 → 64-bit blocks: blocks 0 (64 bits) and
        // 1 (36 bits); with 2 shards, shard 0 owns block 0, shard 1
        // owns the 36-bit tail.
        let layout = ShardLayout::new(100, 8, 2);
        assert_eq!(layout.n_blocks(), 2);
        assert_eq!(layout.owner_of_block(0), 0);
        assert_eq!(layout.owner_of_block(1), 1);
        assert_eq!(layout.owner_of_dim(63), 0);
        assert_eq!(layout.owner_of_dim(64), 1);
        assert_eq!(layout.shard_dims(0), 64);
        assert_eq!(layout.shard_dims(1), 36);
        // Shard dims always partition d.
        for (d, budget, n) in [(100, 8, 2), (1000, 16, 4), (257, 8, 3), (64, 8, 4)] {
            let l = ShardLayout::new(d, budget, n);
            let total: usize = (0..n).map(|s| l.shard_dims(s)).sum();
            assert_eq!(total, d, "d={d} budget={budget} n={n}");
        }
    }

    #[test]
    fn more_shards_than_blocks_leaves_empty_shards() {
        // 64 dims at budget 8 is a single block: shards 1..3 own nothing.
        let layout = ShardLayout::new(64, 8, 4);
        assert_eq!(layout.shard_dims(0), 64);
        for s in 1..4 {
            assert_eq!(layout.shard_dims(s), 0);
        }
    }

    #[test]
    fn bitmap_split_merge_roundtrip() {
        let d = 300;
        let bits: Vec<usize> = (0..d).filter(|i| i % 7 == 0 || i % 11 == 3).collect();
        let full = BitVec::from_indices(d, &bits);
        for n in [1usize, 2, 3, 4] {
            let layout = ShardLayout::new(d, 8, n);
            let parts = layout.split_bitmap(&full);
            assert_eq!(parts.len(), n);
            let ones: usize = parts.iter().map(|p| p.count_ones()).sum();
            assert_eq!(ones, full.count_ones());
            assert_eq!(layout.merge_bitmaps(&parts).unwrap(), full, "n={n}");
        }
    }

    #[test]
    fn merge_bitmaps_rejects_wrong_geometry() {
        let layout = ShardLayout::new(100, 8, 2);
        let full = BitVec::from_indices(100, &[1, 70]);
        let parts = layout.split_bitmap(&full);
        assert!(layout.merge_bitmaps(&parts[..1]).is_err(), "missing shard accepted");
        let bad = vec![BitVec::zeros(64), BitVec::zeros(35)];
        assert!(layout.merge_bitmaps(&bad).is_err(), "short sub-bitmap accepted");
    }

    #[test]
    fn lane_split_merge_reproduces_gia_order() {
        let d = 200;
        let layout = ShardLayout::new(d, 8, 3);
        let gia = BitVec::from_indices(d, &[0, 5, 63, 64, 65, 128, 129, 190, 199]);
        let selected: Vec<usize> = gia.iter_ones().collect();
        // Lane value = 1000 + global dim, so merged order is checkable.
        let per_shard = layout.split_selected(&gia);
        let flat: usize = per_shard.iter().map(|p| p.len()).sum();
        assert_eq!(flat, selected.len());
        for part in &per_shard {
            assert!(part.windows(2).all(|w| w[0] < w[1]), "per-shard order not ascending");
        }
        let parts: Vec<Vec<i32>> = per_shard
            .iter()
            .map(|idxs| idxs.iter().map(|&g| 1000 + g as i32).collect())
            .collect();
        let merged = layout.merge_lanes(&gia, &parts).unwrap();
        let want: Vec<i32> = selected.iter().map(|&g| 1000 + g as i32).collect();
        assert_eq!(merged, want);
    }

    #[test]
    fn merge_lanes_rejects_mismatched_counts() {
        let layout = ShardLayout::new(128, 8, 2);
        let gia = BitVec::from_indices(128, &[0, 64]);
        // Shard 0 owns dim 0, shard 1 owns dim 64 — one lane each.
        assert!(layout.merge_lanes(&gia, &[vec![1], vec![]]).is_err(), "short part accepted");
        assert!(
            layout.merge_lanes(&gia, &[vec![1], vec![2, 3]]).is_err(),
            "long part accepted"
        );
        assert_eq!(layout.merge_lanes(&gia, &[vec![1], vec![2]]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn empty_gia_merges_to_empty_aggregate() {
        let layout = ShardLayout::new(256, 8, 4);
        let gia = BitVec::zeros(256);
        let parts = vec![Vec::new(); 4];
        assert!(layout.merge_lanes(&gia, &parts).unwrap().is_empty());
    }
}
