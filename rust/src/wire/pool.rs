//! Reusable datagram-buffer arena for allocation-free frame emission.
//!
//! Every outgoing frame is one owned `Vec<u8>` (backends hand buffers to
//! the socket and possibly a chaos lane, so borrowing is not an option).
//! Pre-pool, the server allocated one fresh `Vec` per frame per
//! destination per round; [`FrameScratch`] recycles those buffers
//! instead: [`FrameScratch::take`] pops a cleared buffer from the free
//! list (counting a *hit*) or allocates when the list is empty (a
//! *miss*), and [`FrameScratch::give`] returns a transmitted buffer. In
//! steady state every round's emission is served entirely from the pool
//! — `ServerStats::pool_misses` stops moving, which `fediac bench-codec`
//! and `bench-wire` assert.
//!
//! Pooling is an implementation detail of one endpoint: nothing about it
//! is visible on the wire (PROTOCOL.md conformance note).

use crate::wire::{encode_frame_into, Header};

/// Buffers kept on the free list (beyond this, returned buffers are
/// dropped). Bounds worst-case idle memory at `MAX_POOLED` × the largest
/// frame the job emits; generous enough that a full multicast burst
/// (≤ 64 clients × a multi-chunk broadcast) recycles without misses.
const MAX_POOLED: usize = 1024;

/// A free list of datagram buffers with hit/miss accounting.
#[derive(Debug, Default)]
pub struct FrameScratch {
    free: Vec<Vec<u8>>,
    hits: u64,
    misses: u64,
}

impl FrameScratch {
    /// Empty pool (first emissions will miss; steady state will not).
    pub fn new() -> Self {
        FrameScratch::default()
    }

    /// Pop a cleared buffer, or allocate one when the pool is empty.
    pub fn take(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(buf) => {
                self.hits += 1;
                buf
            }
            None => {
                self.misses += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer to the pool (cleared; dropped beyond the cap).
    pub fn give(&mut self, mut buf: Vec<u8>) {
        if self.free.len() < MAX_POOLED {
            buf.clear();
            self.free.push(buf);
        }
    }

    /// Encode one frame into a pooled buffer — the hot-path twin of
    /// [`crate::wire::encode_frame`].
    pub fn encode(&mut self, h: &Header, payload: &[u8]) -> Vec<u8> {
        let mut buf = self.take();
        encode_frame_into(&mut buf, h, payload);
        buf
    }

    /// Copy raw bytes into a pooled buffer (multicast fan-out: the frame
    /// is encoded once, then cloned per destination through the pool).
    pub fn copy(&mut self, bytes: &[u8]) -> Vec<u8> {
        let mut buf = self.take();
        buf.extend_from_slice(bytes);
        buf
    }

    /// Buffers currently parked on the free list.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Take-and-zero the (hits, misses) counters accumulated since the
    /// last drain — owners fold these into their stats periodically.
    pub fn drain_counters(&mut self) -> (u64, u64) {
        let out = (self.hits, self.misses);
        self.hits = 0;
        self.misses = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{decode_frame, WireKind};

    #[test]
    fn steady_state_has_no_misses() {
        let mut pool = FrameScratch::new();
        let h = Header::control(WireKind::Poll, 1, 0, 0, 4);
        // Warm-up: the first burst allocates.
        let burst: Vec<Vec<u8>> = (0..8).map(|_| pool.encode(&h, &[7; 32])).collect();
        let (_, misses) = pool.drain_counters();
        assert_eq!(misses, 8);
        for b in burst {
            pool.give(b);
        }
        // Steady state: same burst size, zero allocations.
        for _ in 0..10 {
            let burst: Vec<Vec<u8>> = (0..8).map(|_| pool.encode(&h, &[9; 32])).collect();
            for b in &burst {
                assert_eq!(decode_frame(b).unwrap().header.kind, WireKind::Poll);
            }
            for b in burst {
                pool.give(b);
            }
        }
        let (hits, misses) = pool.drain_counters();
        assert_eq!(misses, 0, "steady state allocated");
        assert_eq!(hits, 80);
    }

    #[test]
    fn copy_reproduces_bytes_and_reuses_buffers() {
        let mut pool = FrameScratch::new();
        let a = pool.copy(&[1, 2, 3]);
        assert_eq!(a, vec![1, 2, 3]);
        pool.give(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.copy(&[4, 5]);
        assert_eq!(b, vec![4, 5], "stale bytes leaked through the pool");
        assert_eq!(pool.pooled(), 0);
    }
}
