//! Payload bodies: i32 lane packing, job registration specs, and the
//! chunking/reassembly helpers shared by client and server.
//!
//! Framing reuses the repo's existing codecs rather than inventing new
//! ones: vote blocks are byte slices of [`crate::util::BitVec::to_bytes`],
//! GIA broadcasts are [`crate::compress::golomb`] streams, and update /
//! aggregate lanes are the [`crate::compress::quantize`] integers in
//! little-endian order.

use crate::util::BitVec;
use crate::wire::{ShardPlan, WireError};

/// Pack i32 lanes little-endian.
pub fn encode_lanes(lanes: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(lanes.len() * 4);
    encode_lanes_into(&mut out, lanes);
    out
}

/// Pack i32 lanes little-endian into a reused buffer (cleared first) —
/// the allocation-free twin of [`encode_lanes`] the frame-pool emitters
/// use on the per-block hot path.
pub fn encode_lanes_into(out: &mut Vec<u8>, lanes: &[i32]) {
    out.clear();
    out.reserve(lanes.len() * 4);
    for &v in lanes {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Zero-copy lane reader over a payload slice.
pub fn lanes_iter(payload: &[u8]) -> impl Iterator<Item = i32> + '_ {
    payload.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap()))
}

/// Decode i32 lanes; errors when the payload is not a whole number of lanes.
pub fn decode_lanes(payload: &[u8]) -> Result<Vec<i32>, WireError> {
    if payload.len() % 4 != 0 {
        return Err(WireError::BadPayload("lane payload not a multiple of 4 bytes"));
    }
    Ok(lanes_iter(payload).collect())
}

/// Job registration record carried by `Join` frames. Every client of a job
/// must present an identical spec; the first Join creates the job.
///
/// In a sharded deployment (PROTOCOL.md §8) each collaborating server is
/// registered with its *own* spec: `d` is that shard's sub-model
/// dimension and `shard` names the slice, so one server's state machine
/// never needs global knowledge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobSpec {
    /// Model dimension d (vote bitmap length). For a sharded job this is
    /// the *sub-model* dimension the addressed shard owns.
    pub d: u32,
    /// Number of clients N contributing per round.
    pub n_clients: u16,
    /// Voting threshold a (GIA[l] = 1 iff ≥ a votes).
    pub threshold_a: u16,
    /// Payload bytes per data frame — fixes the block geometry both sides
    /// derive (vote: 8·budget bits/block, update: budget/4 lanes/block).
    pub payload_budget: u16,
    /// Shard-plane extension: which slice of a sharded deployment this
    /// spec describes ([`ShardPlan::single`] for unsharded jobs). Encoded
    /// in the two formerly-reserved trailing bytes; a zero `n_shards`
    /// byte (every pre-shard encoder) decodes as the single-server plan,
    /// keeping old and new peers wire-compatible at n_shards = 1.
    pub shard: ShardPlan,
    /// Quorum extension (PROTOCOL.md §11): the minimum number of
    /// complete clients `Q` after which the server may close a phase at
    /// its deadline instead of waiting for all N. `0` means legacy
    /// all-N rounds — and encodes as the legacy 12-byte payload, so a
    /// quorum-disabled deployment stays bit-identical on the wire.
    pub quorum: u16,
}

impl JobSpec {
    /// Wire size of a legacy (quorum-disabled) encoded spec.
    pub const ENCODED_LEN: usize = 12;
    /// Wire size of a quorum-extended encoded spec (§11): the legacy 12
    /// bytes plus the little-endian `quorum` field at bytes 12..14.
    pub const ENCODED_LEN_QUORUM: usize = 14;

    /// Serialise to the `Join` payload: 12 bytes when `quorum == 0`
    /// (bit-identical to every pre-quorum encoder), 14 otherwise.
    pub fn encode(&self) -> Vec<u8> {
        let len =
            if self.quorum == 0 { Self::ENCODED_LEN } else { Self::ENCODED_LEN_QUORUM };
        let mut out = vec![0u8; len];
        out[0..4].copy_from_slice(&self.d.to_le_bytes());
        out[4..6].copy_from_slice(&self.n_clients.to_le_bytes());
        out[6..8].copy_from_slice(&self.threshold_a.to_le_bytes());
        out[8..10].copy_from_slice(&self.payload_budget.to_le_bytes());
        out[10] = self.shard.n_shards;
        out[11] = self.shard.shard_id;
        if self.quorum != 0 {
            out[12..14].copy_from_slice(&self.quorum.to_le_bytes());
        }
        out
    }

    /// Parse and validate a `Join` payload (12- or 14-byte form).
    pub fn decode(payload: &[u8]) -> Result<Self, WireError> {
        if payload.len() != Self::ENCODED_LEN && payload.len() != Self::ENCODED_LEN_QUORUM {
            return Err(WireError::BadPayload("job spec must be 12 or 14 bytes"));
        }
        // Backward-compatible quorum decode, mirroring the shard plane:
        // a 12-byte payload is a pre-quorum encoder and means Q = 0
        // (all-N rounds). A 14-byte payload carrying quorum = 0 is
        // malformed — the canonical zero form is the 12-byte one, and
        // accepting both would break the decode→encode round-trip.
        let quorum = if payload.len() == Self::ENCODED_LEN {
            0
        } else {
            let q = u16::from_le_bytes(payload[12..14].try_into().unwrap());
            if q == 0 {
                return Err(WireError::BadPayload("extended spec with quorum = 0"));
            }
            q
        };
        // Backward-compatible shard decode: encoders predating the shard
        // extension left bytes 10..12 zeroed, which means "unsharded".
        // Only the all-zero form is grandfathered — a zero shard count
        // with a nonzero shard id is malformed, and normalising it away
        // would both violate the strict-decode contract and break the
        // decode→encode round-trip.
        let shard = if payload[10] == 0 {
            if payload[11] != 0 {
                return Err(WireError::BadPayload("shard_id set without n_shards"));
            }
            ShardPlan::single()
        } else {
            ShardPlan { n_shards: payload[10], shard_id: payload[11] }
        };
        let spec = JobSpec {
            d: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
            n_clients: u16::from_le_bytes(payload[4..6].try_into().unwrap()),
            threshold_a: u16::from_le_bytes(payload[6..8].try_into().unwrap()),
            payload_budget: u16::from_le_bytes(payload[8..10].try_into().unwrap()),
            shard,
            quorum,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural validity (independent of any server's memory profile).
    pub fn validate(&self) -> Result<(), WireError> {
        if self.d == 0 {
            return Err(WireError::BadPayload("d must be > 0"));
        }
        if self.n_clients == 0 || self.n_clients > 64 {
            return Err(WireError::BadPayload("n_clients must be in [1, 64]"));
        }
        if self.threshold_a == 0 || self.threshold_a > self.n_clients {
            return Err(WireError::BadPayload("threshold_a must be in [1, N]"));
        }
        if self.payload_budget < 4 || self.payload_budget % 4 != 0 {
            return Err(WireError::BadPayload("payload_budget must be a positive multiple of 4"));
        }
        if self.quorum > self.n_clients {
            return Err(WireError::BadPayload("quorum must be in [0, N]"));
        }
        self.shard.validate()
    }

    /// Vote-phase geometry: bits (= dimensions) per block.
    pub fn vote_block_bits(&self) -> usize {
        self.payload_budget as usize * 8
    }

    /// Vote-phase block count for this model dimension.
    pub fn vote_n_blocks(&self) -> usize {
        (self.d as usize).div_ceil(self.vote_block_bits()).max(1)
    }

    /// Update-phase geometry: i32 lanes per block.
    pub fn update_block_lanes(&self) -> usize {
        self.payload_budget as usize / 4
    }

    /// Update-phase block count for a GIA of `k_s` selected dimensions.
    pub fn update_n_blocks(&self, k_s: usize) -> usize {
        k_s.div_ceil(self.update_block_lanes()).max(1)
    }

    /// Worst-case host bytes one round of this job pins outside the
    /// register file: u16 vote counters (2d), the thresholded GIA bitmap
    /// plus its Golomb stream (≲ d/2 together for any density), and the
    /// i32 update accumulator at k_S = d (4d). Spill memory is bounded
    /// separately by the server's per-round spill cap.
    pub fn host_bytes_per_round(&self) -> usize {
        let d = self.d as usize;
        2 * d + d / 2 + 4 * d
    }
}

/// Vote-phase chunk geometry: for a `d`-bit bitmap at `budget` payload
/// bytes per frame, yields one `(dims_in_block, byte_lo, byte_hi)` per
/// block over the bitmap's wire bytes. The single source of truth for
/// vote chunking — [`vote_chunks`] and the pooled client emitter both
/// iterate it, so their geometry cannot drift.
pub fn vote_chunk_bounds(
    d: usize,
    budget: usize,
) -> impl Iterator<Item = (usize, usize, usize)> {
    let dims_per_block = budget * 8;
    let n_blocks = d.div_ceil(dims_per_block).max(1);
    let total_bytes = d.div_ceil(8);
    (0..n_blocks).map(move |b| {
        let lo_dim = b * dims_per_block;
        let dims = dims_per_block.min(d - lo_dim);
        let lo = b * budget;
        let hi = (lo + dims.div_ceil(8)).min(total_bytes);
        (dims, lo, hi)
    })
}

/// Update-phase chunk geometry: `(lane_lo, lane_hi)` per block of
/// `budget/4` lanes over a `n_lanes`-long stream; a zero-lane stream
/// still yields one empty block (the phase-completion signal). Single
/// source of truth for [`update_chunks`] and the pooled emitters.
pub fn update_chunk_bounds(
    n_lanes: usize,
    budget: usize,
) -> impl Iterator<Item = (usize, usize)> {
    let per_block = (budget / 4).max(1);
    let n_blocks = n_lanes.div_ceil(per_block).max(1);
    (0..n_blocks).map(move |b| {
        let lo = b * per_block;
        let hi = (lo + per_block).min(n_lanes);
        (lo, hi)
    })
}

/// Opaque-stream chunk geometry: `(byte_lo, byte_hi)` per broadcast chunk
/// of at most `budget` bytes; always at least one (possibly empty) chunk.
/// Single source of truth for [`byte_chunks`] and the pooled GIA emitter.
pub fn byte_chunk_bounds(len: usize, budget: usize) -> impl Iterator<Item = (usize, usize)> {
    let budget = budget.max(1);
    let n_blocks = len.div_ceil(budget).max(1);
    (0..n_blocks).map(move |b| {
        let lo = b * budget;
        let hi = (lo + budget).min(len);
        (lo, hi)
    })
}

/// Split a full d-bit vote bitmap into per-block byte payloads of at most
/// `budget` bytes. Returns `(dims_in_block, bytes)` per block; every block
/// but the last covers exactly `8·budget` dimensions, so block i from any
/// client aligns with block i from every other client.
pub fn vote_chunks(bits: &BitVec, budget: usize) -> Vec<(usize, Vec<u8>)> {
    let bytes = bits.to_bytes();
    vote_chunk_bounds(bits.len(), budget)
        .map(|(dims, lo, hi)| (dims, bytes[lo..hi].to_vec()))
        .collect()
}

/// Split i32 lanes into per-block payloads of `budget/4` lanes. Returns
/// `(lanes_in_block, bytes)` per block; a zero-lane stream still yields one
/// empty block so the phase has a completion signal.
pub fn update_chunks(lanes: &[i32], budget: usize) -> Vec<(usize, Vec<u8>)> {
    update_chunk_bounds(lanes.len(), budget)
        .map(|(lo, hi)| (hi - lo, encode_lanes(&lanes[lo..hi])))
        .collect()
}

/// Split an opaque byte stream (e.g. a Golomb-coded GIA) into broadcast
/// chunks of at most `budget` bytes; always at least one (possibly empty).
pub fn byte_chunks(data: &[u8], budget: usize) -> Vec<Vec<u8>> {
    byte_chunk_bounds(data.len(), budget).map(|(lo, hi)| data[lo..hi].to_vec()).collect()
}

/// Reassemble a chunked stream from out-of-order, possibly duplicated
/// frames.
#[derive(Debug, Clone)]
pub struct ChunkAssembler {
    parts: Vec<Option<Vec<u8>>>,
    received: usize,
}

impl ChunkAssembler {
    /// Empty assembler for a stream of `n_blocks` chunks.
    pub fn new(n_blocks: usize) -> Self {
        ChunkAssembler { parts: vec![None; n_blocks.max(1)], received: 0 }
    }

    /// The stream's declared chunk count.
    pub fn n_blocks(&self) -> usize {
        self.parts.len()
    }

    /// Insert one chunk; returns false for duplicates / out-of-range blocks.
    pub fn insert(&mut self, block: usize, bytes: &[u8]) -> bool {
        match self.parts.get_mut(block) {
            Some(slot @ None) => {
                *slot = Some(bytes.to_vec());
                self.received += 1;
                true
            }
            _ => false,
        }
    }

    /// True once every chunk has arrived.
    pub fn is_complete(&self) -> bool {
        self.received == self.parts.len()
    }

    /// Concatenate all chunks in block order (requires completeness).
    pub fn assemble(self) -> Vec<u8> {
        assert!(self.is_complete(), "assembling an incomplete stream");
        let mut out = Vec::new();
        for part in self.parts {
            out.extend_from_slice(&part.unwrap());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_roundtrip() {
        let lanes = vec![0, 1, -1, i32::MAX, i32::MIN, 123_456];
        let bytes = encode_lanes(&lanes);
        assert_eq!(bytes.len(), 24);
        assert_eq!(decode_lanes(&bytes).unwrap(), lanes);
        assert!(decode_lanes(&bytes[..23]).is_err());
    }

    #[test]
    fn job_spec_roundtrip_and_validation() {
        let spec = JobSpec {
            d: 10_000,
            n_clients: 8,
            threshold_a: 3,
            payload_budget: 256,
            shard: ShardPlan::single(),
            quorum: 0,
        };
        assert_eq!(JobSpec::decode(&spec.encode()).unwrap(), spec);
        let bad = JobSpec { threshold_a: 9, ..spec };
        assert!(JobSpec::decode(&bad.encode()).is_err());
        let bad = JobSpec { payload_budget: 10, ..spec };
        assert!(bad.validate().is_err());
        assert!(JobSpec::decode(&[0; 5]).is_err());
    }

    #[test]
    fn quorum_roundtrip_and_backward_compat() {
        let legacy = JobSpec {
            d: 512,
            n_clients: 8,
            threshold_a: 2,
            payload_budget: 16,
            shard: ShardPlan::single(),
            quorum: 0,
        };
        // Q = 0 encodes to the legacy 12-byte form — bit-identical to a
        // pre-quorum encoder.
        assert_eq!(legacy.encode().len(), JobSpec::ENCODED_LEN);
        assert_eq!(JobSpec::decode(&legacy.encode()).unwrap(), legacy);
        // Q > 0 takes the 14-byte extended form and round-trips.
        let quorate = JobSpec { quorum: 5, ..legacy };
        assert_eq!(quorate.encode().len(), JobSpec::ENCODED_LEN_QUORUM);
        assert_eq!(JobSpec::decode(&quorate.encode()).unwrap(), quorate);
        // Quorum must not exceed N.
        let bad = JobSpec { quorum: 9, ..legacy };
        assert!(bad.validate().is_err());
        assert!(JobSpec::decode(&bad.encode()).is_err());
        // A 14-byte payload claiming quorum = 0 is malformed: the
        // canonical Q = 0 form is the 12-byte one.
        let mut mangled = quorate.encode();
        mangled[12] = 0;
        mangled[13] = 0;
        assert!(JobSpec::decode(&mangled).is_err());
        // Truncated extended form (13 bytes) is rejected.
        assert!(JobSpec::decode(&quorate.encode()[..13]).is_err());
    }

    #[test]
    fn shard_plan_roundtrip_and_backward_compat() {
        let spec = JobSpec {
            d: 512,
            n_clients: 4,
            threshold_a: 2,
            payload_budget: 16,
            shard: ShardPlan { n_shards: 4, shard_id: 3 },
            quorum: 0,
        };
        assert_eq!(JobSpec::decode(&spec.encode()).unwrap(), spec);
        // A pre-shard encoder leaves bytes 10..12 zeroed — that must
        // decode as the single-server plan, equal to a modern unsharded
        // spec for the same job parameters.
        let mut legacy = spec.encode();
        legacy[10] = 0;
        legacy[11] = 0;
        let decoded = JobSpec::decode(&legacy).unwrap();
        assert_eq!(decoded.shard, ShardPlan::single());
        assert_eq!(decoded, JobSpec { shard: ShardPlan::single(), ..spec });
        // Invalid plans are refused at decode.
        let bad = JobSpec { shard: ShardPlan { n_shards: 2, shard_id: 2 }, ..spec };
        assert!(JobSpec::decode(&bad.encode()).is_err());
        let bad = JobSpec { shard: ShardPlan { n_shards: 17, shard_id: 0 }, ..spec };
        assert!(bad.validate().is_err());
        // A zero shard count with a nonzero shard id is malformed, not
        // normalised away (strict decode; encode/decode must round-trip).
        let mut mangled = spec.encode();
        mangled[10] = 0;
        mangled[11] = 5;
        assert!(JobSpec::decode(&mangled).is_err());
    }

    #[test]
    fn spec_geometry() {
        let spec = JobSpec {
            d: 100,
            n_clients: 4,
            threshold_a: 2,
            payload_budget: 8,
            shard: ShardPlan::single(),
            quorum: 0,
        };
        assert_eq!(spec.vote_block_bits(), 64);
        assert_eq!(spec.vote_n_blocks(), 2); // 64 + 36 bits
        assert_eq!(spec.update_block_lanes(), 2);
        assert_eq!(spec.update_n_blocks(0), 1);
        assert_eq!(spec.update_n_blocks(5), 3);
        // 2d counters + d/2 GIA forms + 4d accumulator.
        assert_eq!(spec.host_bytes_per_round(), 650);
    }

    #[test]
    fn vote_chunks_align_and_cover() {
        let d = 100;
        let bv = BitVec::from_indices(d, &[0, 63, 64, 65, 99]);
        let chunks = vote_chunks(&bv, 8);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].0, 64);
        assert_eq!(chunks[1].0, 36);
        // Reassembling the chunk bytes reproduces the bitmap.
        let mut bytes = Vec::new();
        for (_, c) in &chunks {
            bytes.extend_from_slice(c);
        }
        assert_eq!(BitVec::from_bytes(d, &bytes), bv);
    }

    #[test]
    fn update_chunks_cover_all_lanes() {
        let lanes: Vec<i32> = (0..10).collect();
        let chunks = update_chunks(&lanes, 16); // 4 lanes per block
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks.iter().map(|(n, _)| n).sum::<usize>(), 10);
        let mut got = Vec::new();
        for (_, c) in &chunks {
            got.extend(decode_lanes(c).unwrap());
        }
        assert_eq!(got, lanes);
        // Empty stream still yields one (empty) block.
        assert_eq!(update_chunks(&[], 16).len(), 1);
    }

    #[test]
    fn assembler_out_of_order_with_duplicates() {
        let chunks = byte_chunks(&(0..=99u8).collect::<Vec<_>>(), 40);
        assert_eq!(chunks.len(), 3);
        let mut asm = ChunkAssembler::new(3);
        assert!(asm.insert(2, &chunks[2]));
        assert!(asm.insert(0, &chunks[0]));
        assert!(!asm.insert(0, &chunks[0]), "duplicate accepted");
        assert!(!asm.is_complete());
        assert!(asm.insert(1, &chunks[1]));
        assert!(asm.is_complete());
        assert_eq!(asm.assemble(), (0..=99u8).collect::<Vec<_>>());
    }
}
