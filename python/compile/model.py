"""L2: JAX model definitions and FL training/eval steps (build-time only).

Every computation the rust coordinator executes per round is defined
here and AOT-lowered by ``aot.py`` to HLO text. Parameters travel as a
single flat ``f32[d]`` vector so the rust side can treat the model as an
opaque dense state vector — exactly what the FediAC compression pipeline
operates on (the paper's U_t^i is the flat update vector).

Models (see DESIGN.md §2 for the CIFAR/FEMNIST substitutions):

* ``tiny``     — 2-layer MLP on 32 synthetic features, 10 classes.
                 Used by fast tests and the quickstart example.
* ``femnist``  — the paper's FEMNIST CNN: 2×(conv → relu → maxpool)
                 followed by 3 fully-connected layers, 28×28×1 input,
                 62 classes (§V-A1). BatchNorm is omitted (stateless
                 flat-parameter contract); documented in DESIGN.md.
* ``cifar10``  — CNN stand-in for ResNet-18 at reduced resolution
                 (16×16×3, 10 classes).
* ``cifar100`` — same trunk, 100-class head.

The local-training step runs the paper's E batch-SGD iterations inside a
``lax.fori_loop`` so one PJRT execution performs a full local round
(Algorithm 1 line 3) with no host round-trips.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant used across the AOT bundle."""

    name: str
    input_shape: tuple  # per-sample shape, e.g. (28, 28, 1) or (32,)
    num_classes: int
    train_batch: int
    eval_batch: int
    local_iters: int  # E in the paper
    conv_channels: tuple = ()  # empty → MLP
    fc_widths: tuple = (64,)

    @property
    def is_conv(self) -> bool:
        return len(self.conv_channels) > 0


# Registry of the model variants shipped in the artifact bundle. E=5
# matches §V-A2; batch sizes are scaled to the single-core CPU testbed.
MODEL_SPECS = {
    "tiny": ModelSpec(
        name="tiny",
        input_shape=(32,),
        num_classes=10,
        train_batch=32,
        eval_batch=128,
        local_iters=5,
        fc_widths=(64,),
    ),
    "femnist": ModelSpec(
        name="femnist",
        input_shape=(28, 28, 1),
        num_classes=62,
        train_batch=16,
        eval_batch=64,
        local_iters=5,
        conv_channels=(8, 16),
        fc_widths=(128, 64),
    ),
    "cifar10": ModelSpec(
        name="cifar10",
        input_shape=(16, 16, 3),
        num_classes=10,
        train_batch=16,
        eval_batch=64,
        local_iters=5,
        conv_channels=(16, 32),
        fc_widths=(256, 128),
    ),
    "cifar100": ModelSpec(
        name="cifar100",
        input_shape=(16, 16, 3),
        num_classes=100,
        train_batch=16,
        eval_batch=64,
        local_iters=5,
        conv_channels=(16, 32),
        fc_widths=(256, 128),
    ),
}


def param_shapes(spec: ModelSpec):
    """Ordered list of (name, shape) pairs defining the flat layout.

    The rust side reads this layout from manifest.json; the flat vector is
    the concatenation of each tensor's row-major elements in this order.
    """
    shapes = []
    if spec.is_conv:
        h, w, c_in = spec.input_shape
        c_prev = c_in
        for idx, c_out in enumerate(spec.conv_channels):
            shapes.append((f"conv{idx}_w", (3, 3, c_prev, c_out)))
            shapes.append((f"conv{idx}_b", (c_out,)))
            c_prev = c_out
            h, w = h // 2, w // 2  # each conv block ends in 2×2 maxpool
        feat = h * w * c_prev
    else:
        (feat,) = spec.input_shape
    widths = list(spec.fc_widths) + [spec.num_classes]
    prev = feat
    for idx, width in enumerate(widths):
        shapes.append((f"fc{idx}_w", (prev, width)))
        shapes.append((f"fc{idx}_b", (width,)))
        prev = width
    return shapes


def param_count(spec: ModelSpec) -> int:
    """Total flat dimension d of the model."""
    total = 0
    for _, shape in param_shapes(spec):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def unpack_params(spec: ModelSpec, flat):
    """Split the flat f32[d] vector into the per-tensor pytree."""
    tensors = {}
    offset = 0
    for name, shape in param_shapes(spec):
        n = 1
        for s in shape:
            n *= s
        tensors[name] = lax.dynamic_slice(flat, (offset,), (n,)).reshape(shape)
        offset += n
    return tensors


def init_params(spec: ModelSpec, seed: int = 0):
    """He-style initialisation, returned as the flat f32[d] vector.

    The classification head (last fc layer) is zero-initialised so the
    initial logits are exactly 0 and the loss starts at ln C with healthy
    gradients — with random-head init the conv stack's maxpool-inflated
    activations saturate the softmax and SGD stalls at chance.
    """
    key = jax.random.PRNGKey(seed)
    head = f"fc{len(spec.fc_widths)}_w"
    parts = []
    for name, shape in param_shapes(spec):
        key, sub = jax.random.split(key)
        if name.endswith("_b") or name == head:
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            scale = jnp.sqrt(2.0 / fan_in)
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * scale).reshape(-1)
            )
    return jnp.concatenate(parts)


def apply_model(spec: ModelSpec, flat, images):
    """Forward pass: images f32[B, *input_shape] → logits f32[B, C]."""
    p = unpack_params(spec, flat)
    x = images
    if spec.is_conv:
        for idx, _ in enumerate(spec.conv_channels):
            x = lax.conv_general_dilated(
                x,
                p[f"conv{idx}_w"],
                window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            x = x + p[f"conv{idx}_b"]
            x = jax.nn.relu(x)
            x = lax.reduce_window(
                x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
        x = x.reshape(x.shape[0], -1)
    n_fc = len(spec.fc_widths) + 1
    for idx in range(n_fc):
        x = x @ p[f"fc{idx}_w"] + p[f"fc{idx}_b"]
        if idx < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy over the batch."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def loss_fn(spec: ModelSpec, flat, images, labels):
    return cross_entropy(apply_model(spec, flat, images), labels)


def make_train_step(spec: ModelSpec):
    """Build the AOT ``train`` entry: E local SGD iterations in one call.

    Signature: (params f32[d], images f32[E,B,…], labels i32[E,B], lr f32[])
    → (new params f32[d], mean local loss f32[]).
    """

    grad_fn = jax.value_and_grad(functools.partial(loss_fn, spec))

    def train_step(params, images, labels, lr):
        def body(j, state):
            p, loss_sum = state
            loss, grads = grad_fn(p, images[j], labels[j])
            return (p - lr * grads, loss_sum + loss)

        p_end, loss_sum = lax.fori_loop(
            0, spec.local_iters, body, (params, jnp.float32(0.0))
        )
        return (p_end, loss_sum / spec.local_iters)

    return train_step


def make_eval_step(spec: ModelSpec):
    """Build the AOT ``eval`` entry.

    Signature: (params f32[d], images f32[B,…], labels i32[B])
    → (correct i32[], mean loss f32[]).
    """

    def eval_step(params, images, labels):
        logits = apply_model(spec, params, images)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))
        return (correct, cross_entropy(logits, labels))

    return eval_step
