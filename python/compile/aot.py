"""AOT compiler: lower every L2/L1 entry point to HLO text artifacts.

This is the single build-time python entry point (``make artifacts``).
For each model variant it emits four artifacts the rust coordinator
loads via ``HloModuleProto::from_text_file``:

* ``train_<model>.hlo.txt``    — E local SGD iterations (Algorithm 1 l.3)
* ``eval_<model>.hlo.txt``     — test-set batch evaluation
* ``compress_<model>.hlo.txt`` — fused Pallas quantise+sparsify+residual
* ``vote_<model>.hlo.txt``     — Pallas Gumbel vote scores
* ``init_<model>.hlo.txt``     — deterministic w₁ initialisation

plus ``manifest.json`` describing shapes/layout so rust can validate.

HLO **text** (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and DESIGN.md §1.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.kernels.compress_kernel import compress_with_seed
from compile.kernels.vote_kernel import vote_scores_with_seed
from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_model(spec: M.ModelSpec):
    """Lower all four entry points for one model variant.

    Returns {artifact_stem: hlo_text}.
    """
    d = M.param_count(spec)
    e, b, eb = spec.local_iters, spec.train_batch, spec.eval_batch
    ishape = spec.input_shape

    train = jax.jit(M.make_train_step(spec))
    eval_ = jax.jit(M.make_eval_step(spec))

    def compress(updates, gia, f, seed):
        return compress_with_seed(updates, gia, f, seed)

    def vote(updates, seed):
        return (vote_scores_with_seed(updates, seed),)

    def init():
        return (M.init_params(spec, seed=0),)

    out = {}
    out[f"init_{spec.name}"] = to_hlo_text(jax.jit(init).lower())
    out[f"train_{spec.name}"] = to_hlo_text(
        train.lower(_f32(d), _f32(e, b, *ishape), _i32(e, b), _f32())
    )
    out[f"eval_{spec.name}"] = to_hlo_text(
        eval_.lower(_f32(d), _f32(eb, *ishape), _i32(eb))
    )
    out[f"compress_{spec.name}"] = to_hlo_text(
        jax.jit(compress).lower(_f32(d), _f32(d), _f32(), _i32())
    )
    out[f"vote_{spec.name}"] = to_hlo_text(jax.jit(vote).lower(_f32(d), _i32()))
    return out


def manifest_entry(spec: M.ModelSpec) -> dict:
    return {
        "name": spec.name,
        "d": M.param_count(spec),
        "input_shape": list(spec.input_shape),
        "num_classes": spec.num_classes,
        "train_batch": spec.train_batch,
        "eval_batch": spec.eval_batch,
        "local_iters": spec.local_iters,
        "layout": [
            {"tensor": name, "shape": list(shape)}
            for name, shape in M.param_shapes(spec)
        ],
        "init_params_seed": 0,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="tiny,femnist,cifar10,cifar100",
        help="comma-separated subset of: " + ",".join(M.MODEL_SPECS),
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        spec = M.MODEL_SPECS[name]
        print(f"[aot] lowering {name} (d={M.param_count(spec)}) ...", flush=True)
        artifacts = lower_model(spec)
        entry = manifest_entry(spec)
        entry["artifacts"] = {}
        for stem, text in artifacts.items():
            path = os.path.join(args.out_dir, f"{stem}.hlo.txt")
            with open(path, "w") as fh:
                fh.write(text)
            entry["artifacts"][stem.split("_")[0]] = f"{stem}.hlo.txt"
            print(
                f"[aot]   {stem}.hlo.txt  {len(text)} chars  "
                f"sha1={hashlib.sha1(text.encode()).hexdigest()[:12]}",
                flush=True,
            )
        manifest["models"][name] = entry
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'manifest.json')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
