"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

These implementations are deliberately written in the most direct jnp
style so that they can be audited against the paper's equations:

* :func:`ref_quantize_sparsify` — Eq. (1) unbiased stochastic integer
  quantisation composed with the GIA sparsification Π, plus the
  residual-error update e = (fU − Π(Θ(fU)))/f from Algorithm 1 line 9.
* :func:`ref_vote_scores` — the Gumbel perturbation whose top-k equals
  sampling k elements without replacement with probability proportional
  to the update magnitude (the paper's "odds proportional to its
  magnitude" vote, §IV step 1).

The Pallas kernels in ``compress_kernel.py`` / ``vote_kernel.py`` must
match these bit-for-bit given the same pre-drawn noise.
"""

from __future__ import annotations

import jax.numpy as jnp

# Small epsilon so that log|u| is finite for exactly-zero updates. A zero
# update gets a score of log(EPS) + gumbel — astronomically unlikely to be
# voted, matching the paper (zero-magnitude updates carry no information).
VOTE_EPS = 1e-30


def ref_quantize_sparsify(updates, gia, f, noise):
    """Reference Π(Θ(f·U)) and residual.

    Args:
      updates: f32[d] local model updates U (residual already folded in).
      gia: f32[d] global index array of 0.0/1.0 (the consensus mask).
      f: scalar amplification factor f = (2^{b-1} − N)/(N·m).
      noise: f32[d] uniform(0,1) noise that drives the stochastic rounding.

    Returns:
      (q, residual): q = i32[d] quantised+sparsified integers,
      residual = f32[d] with e = (f·U − Π(Θ(f·U)))/f.
    """
    amplified = updates * f
    low = jnp.floor(amplified)
    frac = amplified - low
    # Round up with probability equal to the fractional part: E[θ(x)] = x.
    rounded = low + (noise < frac).astype(amplified.dtype)
    q = (rounded * gia).astype(jnp.int32)
    residual = (amplified - q.astype(amplified.dtype)) / f
    return q, residual


def ref_vote_scores(updates, noise):
    """Reference Gumbel vote scores.

    top_k(scores) realises Plackett–Luce sampling of k indices without
    replacement with probability ∝ |U_l| (Gumbel-top-k identity).

    Args:
      updates: f32[d] local model updates.
      noise: f32[d] uniform(0,1) noise.

    Returns:
      f32[d] perturbed log-magnitude scores.
    """
    gumbel = -jnp.log(-jnp.log(noise))
    return jnp.log(jnp.abs(updates) + VOTE_EPS) + gumbel


def ref_quantize_dense(updates, f, noise):
    """Dense unbiased quantisation used by the SwitchML baseline model.

    Identical to :func:`ref_quantize_sparsify` with an all-ones mask.
    """
    ones = jnp.ones_like(updates)
    return ref_quantize_sparsify(updates, ones, f, noise)
