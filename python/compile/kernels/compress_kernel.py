"""L1 Pallas kernel: fused stochastic-quantise + GIA-sparsify + residual.

This is the client-side compression hot spot of FediAC (§IV step 3 /
Algorithm 1 lines 8–9). One streaming sweep over the d-length update
vector performs:

    amplified = f · U
    θ(amplified)  — unbiased stochastic rounding, Eq. (1)
    Π(·)          — multiply by the 0/1 GIA mask
    e             — residual (f·U − Π(Θ(f·U)))/f

fused into a single HBM→VMEM→HBM pass. On a real TPU the BlockSpec
below tiles the vector into VMEM-resident blocks of ``BLOCK`` lanes;
each block reads 3 f32 inputs and writes 1 i32 + 1 f32 output, so the
kernel is memory-bandwidth-bound (no MXU work) and the roofline is a
single round trip over 5·4·d bytes. ``interpret=True`` is mandatory on
the CPU PJRT backend (real lowering emits a Mosaic custom-call the CPU
plugin cannot execute) — see DESIGN.md §Hardware-Adaptation.

The uniform rounding noise is drawn in L2 (threefry) and passed in, so
the kernel is a pure function and bit-identical to ``ref.py`` given the
same noise — that identity is what ``python/tests/test_kernel.py``
asserts over hypothesis-swept shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4 KiB of f32 lanes per block: small enough that (3 in + 2 out) blocks fit
# comfortably in a ~16 MiB VMEM budget even with double buffering, large
# enough to amortise grid overhead. d is padded to a multiple of this.
BLOCK = 1024


def _compress_block_kernel(u_ref, gia_ref, noise_ref, f_ref, q_ref, res_ref):
    """Per-block body: fused amplify → stochastic round → mask → residual."""
    f = f_ref[0]
    amplified = u_ref[...] * f
    low = jnp.floor(amplified)
    frac = amplified - low
    rounded = low + (noise_ref[...] < frac).astype(amplified.dtype)
    q = rounded * gia_ref[...]
    q_ref[...] = q.astype(jnp.int32)
    res_ref[...] = (amplified - q) / f


@functools.partial(jax.jit, static_argnames=("block",))
def compress_pallas(updates, gia, f, noise, *, block=BLOCK):
    """Fused Π(Θ(f·U)) + residual via a tiled Pallas kernel.

    Args:
      updates: f32[d] local updates (with residual folded in by the caller).
      gia: f32[d] consensus mask of 0.0/1.0 from the PS.
      f: f32 scalar amplification factor.
      noise: f32[d] uniform(0,1) stochastic-rounding noise.
      block: VMEM tile width in lanes.

    Returns:
      (q i32[d], residual f32[d]).
    """
    d = updates.shape[0]
    padded = pl.cdiv(d, block) * block
    pad = padded - d
    u_p = jnp.pad(updates, (0, pad))
    gia_p = jnp.pad(gia, (0, pad))
    # Pad noise with 1.0 so padded lanes never round up (frac < 1 always).
    noise_p = jnp.pad(noise, (0, pad), constant_values=1.0)
    f_arr = jnp.reshape(f.astype(jnp.float32) if hasattr(f, "astype") else jnp.float32(f), (1,))

    grid = padded // block
    q, res = pl.pallas_call(
        _compress_block_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            # The scalar factor is broadcast to every block.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), jnp.int32),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=True,
    )(u_p, gia_p, noise_p, f_arr)
    return q[:d], res[:d]


def compress_with_seed(updates, gia, f, seed):
    """Seed-driven wrapper used by the AOT entry point.

    Draws the uniform rounding noise from a threefry key derived from
    ``seed`` (i32 scalar) and invokes the fused kernel. This is the exact
    computation the rust coordinator executes per client per round via the
    ``compress_<model>.hlo.txt`` artifact.
    """
    key = jax.random.PRNGKey(seed.astype(jnp.uint32) if hasattr(seed, "astype") else seed)
    noise = jax.random.uniform(key, updates.shape, dtype=jnp.float32)
    return compress_pallas(updates, gia, f, noise)
