"""L1 Pallas kernel: Gumbel vote-score computation (§IV step 1).

FediAC clients "vote k elements using odds proportional to their
magnitude" (Algorithm 1 line 5). Sampling k indices without replacement
with probability ∝ |U_l| is exactly the Gumbel-top-k construction:

    score_l = log|U_l| + Gumbel_l,   vote = top-k(score)

This kernel computes the perturbed scores in one streaming pass; the
coordinator (rust L3) performs the top-k selection so that k stays a
runtime parameter instead of being baked into the artifact. Like the
compress kernel this is elementwise and bandwidth-bound: 2 f32 reads +
1 f32 write per lane, tiled into VMEM blocks via BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import VOTE_EPS

BLOCK = 1024


def _vote_block_kernel(u_ref, noise_ref, score_ref):
    gumbel = -jnp.log(-jnp.log(noise_ref[...]))
    score_ref[...] = jnp.log(jnp.abs(u_ref[...]) + VOTE_EPS) + gumbel


@functools.partial(jax.jit, static_argnames=("block",))
def vote_scores_pallas(updates, noise, *, block=BLOCK):
    """Perturbed log-magnitude scores; top-k of the result is the vote.

    Args:
      updates: f32[d] local updates.
      noise: f32[d] uniform(0,1) noise (open interval enforced by caller).
      block: VMEM tile width in lanes.

    Returns:
      f32[d] scores.
    """
    d = updates.shape[0]
    padded = pl.cdiv(d, block) * block
    pad = padded - d
    u_p = jnp.pad(updates, (0, pad))
    # 0.5 keeps the padded-lane double log finite; the lanes are sliced off.
    noise_p = jnp.pad(noise, (0, pad), constant_values=0.5)
    grid = padded // block
    scores = pl.pallas_call(
        _vote_block_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(u_p, noise_p)
    return scores[:d]


def vote_scores_with_seed(updates, seed):
    """Seed-driven wrapper for the AOT ``vote_<model>`` artifact."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32) if hasattr(seed, "astype") else seed)
    # Clamp into the open interval so -log(-log(u)) is finite.
    noise = jax.random.uniform(
        key, updates.shape, dtype=jnp.float32, minval=1e-7, maxval=1.0 - 1e-7
    )
    return vote_scores_pallas(updates, noise)
