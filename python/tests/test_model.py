"""L2 correctness: model shapes, flat-parameter layout, learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.mark.parametrize("name", list(M.MODEL_SPECS))
def test_param_count_matches_layout(name):
    spec = M.MODEL_SPECS[name]
    total = sum(int(np.prod(s)) for _, s in M.param_shapes(spec))
    assert total == M.param_count(spec)
    flat = M.init_params(spec)
    assert flat.shape == (total,)
    assert flat.dtype == jnp.float32


@pytest.mark.parametrize("name", list(M.MODEL_SPECS))
def test_forward_shapes(name):
    spec = M.MODEL_SPECS[name]
    flat = M.init_params(spec)
    b = 4
    images = jnp.zeros((b, *spec.input_shape), jnp.float32)
    logits = M.apply_model(spec, flat, images)
    assert logits.shape == (b, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def _synthetic_batch(spec, rng, batch):
    """Linearly separable class-conditional Gaussian batch."""
    labels = rng.integers(0, spec.num_classes, batch)
    feat_shape = spec.input_shape
    templates = np.stack(
        [
            np.random.default_rng(100 + c).normal(0, 1, feat_shape)
            for c in range(spec.num_classes)
        ]
    )
    images = templates[labels] + rng.normal(0, 0.3, (batch, *feat_shape))
    return (
        jnp.asarray(images.astype(np.float32)),
        jnp.asarray(labels.astype(np.int32)),
    )


@pytest.mark.parametrize("name", ["tiny", "femnist"])
def test_train_step_reduces_loss(name):
    spec = M.MODEL_SPECS[name]
    train = jax.jit(M.make_train_step(spec))
    rng = np.random.default_rng(0)
    flat = M.init_params(spec)
    e, b = spec.local_iters, spec.train_batch
    losses = []
    for step in range(6):
        imgs, labels = _synthetic_batch(spec, rng, e * b)
        imgs = imgs.reshape(e, b, *spec.input_shape)
        labels = labels.reshape(e, b)
        flat, loss = train(flat, imgs, labels, jnp.float32(0.05))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"no learning signal: {losses}"


def test_eval_step_counts_correct():
    spec = M.MODEL_SPECS["tiny"]
    eval_ = jax.jit(M.make_eval_step(spec))
    flat = M.init_params(spec)
    rng = np.random.default_rng(1)
    imgs, labels = _synthetic_batch(spec, rng, spec.eval_batch)
    correct, loss = eval_(flat, imgs, labels)
    assert 0 <= int(correct) <= spec.eval_batch
    assert np.isfinite(float(loss))


def test_eval_perfect_on_trained_tiny():
    """After enough steps the tiny MLP must fit an easy synthetic task."""
    spec = M.MODEL_SPECS["tiny"]
    train = jax.jit(M.make_train_step(spec))
    eval_ = jax.jit(M.make_eval_step(spec))
    rng = np.random.default_rng(2)
    flat = M.init_params(spec)
    e, b = spec.local_iters, spec.train_batch
    for _ in range(30):
        imgs, labels = _synthetic_batch(spec, rng, e * b)
        flat, _ = train(
            flat,
            imgs.reshape(e, b, *spec.input_shape),
            labels.reshape(e, b),
            jnp.float32(0.05),
        )
    imgs, labels = _synthetic_batch(spec, rng, spec.eval_batch)
    correct, _ = eval_(flat, imgs, labels)
    assert int(correct) >= 0.9 * spec.eval_batch


def test_unpack_roundtrip():
    spec = M.MODEL_SPECS["femnist"]
    flat = M.init_params(spec, seed=3)
    tensors = M.unpack_params(spec, flat)
    rebuilt = jnp.concatenate([tensors[n].reshape(-1) for n, _ in M.param_shapes(spec)])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_update_vector_is_flat_difference():
    """U = w_0 − w_E: the quantity FediAC compresses is well-defined."""
    spec = M.MODEL_SPECS["tiny"]
    train = jax.jit(M.make_train_step(spec))
    rng = np.random.default_rng(4)
    flat0 = M.init_params(spec)
    e, b = spec.local_iters, spec.train_batch
    imgs, labels = _synthetic_batch(spec, rng, e * b)
    flat1, _ = train(
        flat0,
        imgs.reshape(e, b, *spec.input_shape),
        labels.reshape(e, b),
        jnp.float32(0.05),
    )
    u = np.asarray(flat0) - np.asarray(flat1)
    assert u.shape == (M.param_count(spec),)
    assert np.abs(u).max() > 0.0
