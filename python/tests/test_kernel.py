"""L1 correctness: Pallas kernels vs the pure-jnp oracle in ref.py.

This is the core correctness signal for the compression hot path:
hypothesis sweeps shapes (block-boundary adjacent), factors and seeds,
and asserts the fused Pallas kernel is bit-identical to the reference
given the same noise, plus the paper-level invariants:

* unbiasedness  E[θ(fU)] = fU                       (Eq. 1)
* bounded error E[θ(x) − x]² − x² ≤ 0.25            (Appendix A, Eq. 8)
* residual identity f·U = Π(Θ(f·U)) + f·e           (Algorithm 1 l.9)
* Gumbel vote frequencies ∝ |U|                     (§IV step 1)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.compress_kernel import compress_pallas, compress_with_seed
from compile.kernels.vote_kernel import vote_scores_pallas, vote_scores_with_seed


def _updates(d, seed, scale=0.05):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, d).astype(np.float32))


def _mask(d, seed, p=0.3):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray((rng.random(d) < p).astype(np.float32))


def _noise(d, seed):
    rng = np.random.default_rng(seed + 2)
    return jnp.asarray(rng.random(d).astype(np.float32))


# Shapes straddling the pallas BLOCK=1024 boundary plus small odd sizes.
dims = st.sampled_from([1, 3, 17, 256, 1023, 1024, 1025, 3000, 4096])


@settings(max_examples=20, deadline=None)
@given(d=dims, seed=st.integers(0, 2**16), f=st.floats(8.0, 4096.0))
def test_compress_matches_ref(d, seed, f):
    """Fused Pallas kernel ≡ ref.py bit-for-bit given identical noise."""
    u, gia, noise = _updates(d, seed), _mask(d, seed), _noise(d, seed)
    f = jnp.float32(f)
    q_k, r_k = compress_pallas(u, gia, f, noise)
    q_r, r_r = ref.ref_quantize_sparsify(u, gia, f, noise)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(d=dims, seed=st.integers(0, 2**16))
def test_vote_matches_ref(d, seed):
    u, noise = _updates(d, seed), _noise(d, seed)
    noise = jnp.clip(noise, 1e-7, 1.0 - 1e-7)
    s_k = vote_scores_pallas(u, noise)
    s_r = ref.ref_vote_scores(u, noise)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([64, 1000, 1025]),
    seed=st.integers(0, 2**16),
    block=st.sampled_from([16, 64, 1024]),
)
def test_compress_block_size_invariance(d, seed, block):
    """Tiling must not change the numbers: any block size gives the same q."""
    u, gia, noise = _updates(d, seed), _mask(d, seed), _noise(d, seed)
    f = jnp.float32(512.0)
    q_a, r_a = compress_pallas(u, gia, f, noise, block=block)
    q_b, r_b = compress_pallas(u, gia, f, noise, block=1024)
    np.testing.assert_array_equal(np.asarray(q_a), np.asarray(q_b))
    np.testing.assert_allclose(np.asarray(r_a), np.asarray(r_b))


def test_quantization_unbiased_monte_carlo():
    """Across many seeds, mean of θ(fU) approaches fU (Eq. 1 unbiasedness)."""
    d = 256
    u = _updates(d, 7)
    gia = jnp.ones(d, jnp.float32)
    f = jnp.float32(333.0)
    total = np.zeros(d, np.float64)
    trials = 400
    for s in range(trials):
        q, _ = compress_with_seed(u, gia, f, jnp.int32(s))
        total += np.asarray(q, np.float64)
    mean_q = total / trials
    target = np.asarray(u) * float(f)
    # Std of a single stochastic round is ≤ 0.5 ⇒ CI ≈ 4·0.5/sqrt(trials).
    np.testing.assert_allclose(mean_q, target, atol=4 * 0.5 / np.sqrt(trials))


def test_residual_identity_exact():
    """f·U = q + f·e wherever the mask is 1; e = U where the mask is 0."""
    d = 2048
    u, gia, noise = _updates(d, 11), _mask(d, 11, p=0.5), _noise(d, 11)
    f = jnp.float32(1024.0)
    q, res = compress_pallas(u, gia, f, noise)
    q = np.asarray(q, np.float64)
    res = np.asarray(res, np.float64)
    un = np.asarray(u, np.float64)
    np.testing.assert_allclose(q + float(f) * res, float(f) * un, rtol=1e-5, atol=1e-3)
    off = np.asarray(gia) == 0.0
    assert np.all(q[off] == 0.0)
    np.testing.assert_allclose(res[off], un[off], rtol=1e-6, atol=1e-8)


def test_quantization_error_bound():
    """Per-element squared rounding error never exceeds 0.25 + x² (Eq. 8)."""
    d = 4096
    u, noise = _updates(d, 13, scale=0.1), _noise(d, 13)
    gia = jnp.ones(d, jnp.float32)
    f = jnp.float32(777.0)
    q, _ = compress_pallas(u, gia, f, noise)
    err = np.asarray(q, np.float64) - np.asarray(u, np.float64) * float(f)
    assert np.max(np.abs(err)) <= 1.0 + 1e-6  # stochastic round moves < 1 ulp-int


def test_masked_lanes_transmit_nothing():
    """Π must zero every unvoted dimension regardless of magnitude."""
    d = 512
    u = jnp.asarray(np.full(d, 123.456, np.float32))
    gia = jnp.zeros(d, jnp.float32)
    q, res = compress_with_seed(u, gia, jnp.float32(100.0), jnp.int32(3))
    assert np.all(np.asarray(q) == 0)
    np.testing.assert_allclose(np.asarray(res), np.asarray(u), rtol=1e-6)


def test_vote_frequencies_track_magnitude():
    """Top-k of the Gumbel scores selects large-|U| dims far more often."""
    d = 200
    k = 20
    mags = np.ones(d, np.float32) * 0.001
    mags[:10] = 10.0  # ten dominant dimensions
    u = jnp.asarray(mags)
    hits = np.zeros(d)
    trials = 200
    for s in range(trials):
        scores = vote_scores_with_seed(u, jnp.int32(s))
        top = np.argsort(-np.asarray(scores))[:k]
        hits[top] += 1
    # The dominant dims should be voted essentially always, the rest rarely.
    assert hits[:10].min() >= 0.95 * trials
    assert hits[10:].mean() <= 0.2 * trials


def test_vote_deterministic_per_seed():
    u = _updates(1024, 21)
    a = vote_scores_with_seed(u, jnp.int32(5))
    b = vote_scores_with_seed(u, jnp.int32(5))
    c = vote_scores_with_seed(u, jnp.int32(6))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_compress_seed_determinism():
    d = 1500
    u, gia = _updates(d, 31), _mask(d, 31)
    f = jnp.float32(256.0)
    q1, r1 = compress_with_seed(u, gia, f, jnp.int32(9))
    q2, r2 = compress_with_seed(u, gia, f, jnp.int32(9))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
