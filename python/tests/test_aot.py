"""AOT bundle sanity: lowering emits parseable HLO text + a correct manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_tiny_lowering_emits_hlo_text():
    artifacts = aot.lower_model(M.MODEL_SPECS["tiny"])
    assert set(artifacts) == {
        "train_tiny",
        "eval_tiny",
        "compress_tiny",
        "vote_tiny",
        "init_tiny",
    }
    for stem, text in artifacts.items():
        assert text.startswith("HloModule"), f"{stem} does not look like HLO text"
        assert "ENTRY" in text
        # jax ≥ 0.5 protos are rejected by xla_extension 0.5.1; text must be
        # the interchange — make sure nobody switched to .serialize().
        assert isinstance(text, str)


def test_manifest_entry_layout():
    spec = M.MODEL_SPECS["femnist"]
    entry = aot.manifest_entry(spec)
    assert entry["d"] == M.param_count(spec)
    total = 0
    for item in entry["layout"]:
        n = 1
        for s in item["shape"]:
            n *= s
        total += n
    assert total == entry["d"]
    assert entry["num_classes"] == 62
    assert entry["local_iters"] == 5


def test_artifact_dir_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--models", "tiny"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert "tiny" in manifest["models"]
    for stem in ["train_tiny", "eval_tiny", "compress_tiny", "vote_tiny", "init_tiny"]:
        p = out / f"{stem}.hlo.txt"
        assert p.exists() and p.stat().st_size > 100
