//! E7: validate §IV-B analytically *and* by Monte Carlo.
//!
//! For a grid of thresholds a, compares Proposition 1's analytic r_l /
//! E[k_S] / γ against simulated voting (clients draw Gumbel-top-k votes
//! over power-law magnitudes; the GIA is deduced exactly as the switch
//! does), and prints Corollary 1's minimal b alongside.
//!
//! ```bash
//! cargo run --release --example theory_explorer
//! ```

use fediac::compress::{deduce_gia, quantize_sparsify, scale_factor, vote_bitmap};
use fediac::theory::{min_bits, prop1_evaluate, PowerLaw, Prop1Params};
use fediac::util::{BitVec, Rng};

fn main() {
    let d = 20_000;
    let n = 20;
    let k = d / 20; // 5%·d, the paper default
    let law = PowerLaw { phi: 0.1, alpha: -0.7 };
    let trials = 8;

    // Power-law magnitudes, shuffled so index ≠ rank.
    let mut rng = Rng::new(42);
    let mut mags: Vec<f32> = (1..=d).map(|l| law.magnitude(l) as f32).collect();
    let mut index_of_rank: Vec<usize> = (0..d).collect();
    rng.shuffle(&mut index_of_rank);
    let mut updates = vec![0.0f32; d];
    for (rank, &idx) in index_of_rank.iter().enumerate() {
        updates[idx] = mags[rank] * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
    }
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());

    println!("E7: Prop.1 / Cor.1 vs Monte Carlo  (d={d}, N={n}, k={k}, α={}, φ={})", law.alpha, law.phi);
    println!("a\tE[k_S] analytic\tE[k_S] simulated\tγ analytic\tγ̂ simulated\tmin b (Cor.1)");
    for a in [1usize, 2, 3, 4, 6, 8] {
        let b = min_bits(d, n, k, a, &law);
        let out = prop1_evaluate(&Prop1Params {
            d,
            n_clients: n,
            k,
            threshold_a: a,
            law,
            bits_b: b,
        });

        // Monte Carlo: N clients vote; GIA deduced; empirical γ̂ measured
        // with the actual quantiser.
        let mut sim_ks = 0.0;
        let mut sim_gamma = 0.0;
        for t in 0..trials {
            let mut trng = Rng::new(1000 + t as u64);
            let votes: Vec<BitVec> =
                (0..n).map(|_| vote_bitmap(&updates, k, &mut trng)).collect();
            let gia = deduce_gia(&votes, a);
            sim_ks += gia.count_ones() as f64;
            let f = scale_factor(b, n, fediac::compress::max_abs(&updates));
            let mask = gia.to_f32_mask();
            let (q, _) = quantize_sparsify(&updates, &mask, f, &mut trng);
            sim_gamma += fediac::compress::error::relative_error(&q, &updates, f);
        }
        sim_ks /= trials as f64;
        sim_gamma /= trials as f64;
        println!(
            "{a}\t{:.1}\t{:.1}\t{:.4}\t{:.4}\t{b}",
            out.expected_uploads, sim_ks, out.gamma, sim_gamma
        );
    }
    println!(
        "\nNotes: analytic γ is an upper bound (Prop. 1), so γ̂ ≤ γ is expected;\n\
         E[k_S] should track the simulation closely. Larger a ⇒ fewer uploads,\n\
         larger sparsification error — the trade-off FediAC tunes with a."
    );
}
