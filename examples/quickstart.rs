//! Quickstart: train a small model with FediAC through the full simulated
//! in-network stack (native backend — no artifacts needed).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fediac::configx::{AlgorithmKind, DatasetKind, ExperimentConfig, Partition};
use fediac::experiments::{run, RunOptions};

fn main() -> anyhow::Result<()> {
    // 8 clients, IID synthetic task, high-performance switch.
    let mut cfg = ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg.num_clients = 8;
    cfg.rounds = 20;
    cfg.samples_per_client = 80;

    println!("FediAC quickstart: {}", cfg.label());
    println!("round  sim_time_s  train_loss  accuracy  traffic_mb");
    let rec = run(&cfg, &RunOptions { eval_every: 2, ..Default::default() })?;
    for (i, r) in rec.records.iter().enumerate() {
        if let Some(acc) = r.test_accuracy {
            println!(
                "{:>5}  {:>10.3}  {:>10.4}  {:>8.4}  {:>10.3}",
                r.round,
                r.sim_time_s,
                r.train_loss,
                acc,
                rec.cumulative_traffic(i).total_mb()
            );
        }
    }
    println!(
        "\nbest accuracy {:.4} | total traffic {:.2} MB | simulated time {:.2} s",
        rec.best_accuracy().unwrap_or(0.0),
        rec.total_traffic().total_mb(),
        rec.final_time()
    );
    Ok(())
}
