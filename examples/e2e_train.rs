//! E10: the end-to-end driver — every layer composed on a real workload.
//!
//! Trains the FEMNIST CNN (L2 JAX model + L1 Pallas compress/vote kernels,
//! AOT-lowered to HLO and executed through the PJRT C API) across 20
//! simulated clients coordinated by the FediAC protocol over the
//! programmable-switch + M/G/1 network simulation. A few hundred local
//! SGD steps total (rounds × E × clients), loss curve and traffic logged;
//! the run is recorded in EXPERIMENTS.md §E10.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_train -- [rounds] [dataset]
//! ```

use fediac::configx::{AlgorithmKind, BackendKind, DatasetKind, ExperimentConfig, Partition};
use fediac::experiments::{run, RunOptions};
use fediac::runtime::artifacts_available;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(60);
    let dataset = args
        .get(1)
        .and_then(|a| DatasetKind::parse(a))
        .unwrap_or(DatasetKind::SynthFemnist);

    anyhow::ensure!(
        artifacts_available("artifacts"),
        "no AOT bundle — run `make artifacts` first"
    );

    let partition =
        if dataset == DatasetKind::SynthFemnist { Partition::Natural } else { Partition::Iid };
    let mut cfg = ExperimentConfig::preset(dataset, partition);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg.backend = BackendKind::Pjrt;
    cfg.num_clients = 20;
    cfg.rounds = rounds;
    cfg.samples_per_client = 200;

    let total_steps = cfg.rounds * cfg.local_iters;
    eprintln!(
        "e2e: {} | PJRT backend | {} clients | {} rounds × E={} = {} local steps/client",
        cfg.label(),
        cfg.num_clients,
        cfg.rounds,
        cfg.local_iters,
        total_steps
    );

    let t0 = std::time::Instant::now();
    let rec = run(&cfg, &RunOptions { eval_every: 4, verbose: true, ..Default::default() })?;
    let wall = t0.elapsed().as_secs_f64();

    println!("{}", rec.to_csv());
    rec.write_csv(&format!("results/e2e_{}.csv", cfg.label()))?;
    eprintln!(
        "\ne2e summary: best_acc={:.4} | final train loss={:.4} | sim_time={:.1}s | \
         traffic={:.2} MB | wall={:.1}s ({:.2} s/round)",
        rec.best_accuracy().unwrap_or(0.0),
        rec.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN),
        rec.final_time(),
        rec.total_traffic().total_mb(),
        wall,
        wall / rec.records.len().max(1) as f64
    );
    Ok(())
}
