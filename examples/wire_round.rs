//! Loopback demo of the networked FediAC service: an in-process UDP
//! aggregation server, four client drivers on threads, two full
//! vote → GIA → update → aggregate rounds with residual feedback, and a
//! cross-check against the host-side reference primitives.
//!
//! ```bash
//! cargo run --release --example wire_round
//! ```
//!
//! The same protocol runs across machines via the CLI:
//! `fediac serve` on one host, `fediac client` on the others.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use fediac::client::{protocol, ClientOptions, FediacClient};
use fediac::compress::deduce_gia;
use fediac::server::{serve, ServeOptions};
use fediac::util::Rng;

const N: usize = 4;
const D: usize = 4096;
const JOB: u32 = 1;
const SEED: u64 = 7;
const ROUNDS: usize = 2;

fn main() -> anyhow::Result<()> {
    let handle = serve(&ServeOptions::default())?;
    let addr = handle.local_addr();
    println!("aggregation server on {addr} — {N} clients, d={D}, {ROUNDS} rounds\n");

    let k = protocol::votes_per_client(D, 0.05);
    let retx_total = AtomicU64::new(0);

    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for id in 0..N {
            let retx_total = &retx_total;
            handles.push(scope.spawn(move || -> anyhow::Result<Vec<Vec<usize>>> {
                let mut opts =
                    ClientOptions::new(addr.to_string(), JOB, id as u16, D, N as u16);
                opts.threshold_a = 2;
                opts.k = k;
                opts.backend_seed = SEED;
                opts.timeout = Duration::from_millis(300);
                let mut client = FediacClient::connect(opts)?;
                let mut residual = vec![0.0f32; D];
                let mut selected_per_round = Vec::new();
                for round in 1..=ROUNDS {
                    // Deterministic synthetic "local update" + residual.
                    let mut rng = Rng::new(SEED ^ (id as u64) << 32 ^ round as u64);
                    let mut update: Vec<f32> =
                        (0..D).map(|_| (rng.gaussian() * 0.01) as f32).collect();
                    for (u, r) in update.iter_mut().zip(&residual) {
                        *u += *r;
                    }
                    let out = client.run_round(round, &update)?;
                    residual = out.residual;
                    if id == 0 {
                        let l2: f64 = out
                            .delta
                            .iter()
                            .map(|&x| f64::from(x) * f64::from(x))
                            .sum::<f64>()
                            .sqrt();
                        println!(
                            "round {round}: k_S = {:>4} ({:.2}% of d)  f = {:>8.1}  \
                             |delta|2 = {l2:.3e}",
                            out.gia_indices.len(),
                            100.0 * out.gia_indices.len() as f64 / D as f64,
                            out.scale_f,
                        );
                        // Round 1 has no residual history, so every
                        // client's vote is derivable from the shared seed:
                        // cross-check the switch's consensus against the
                        // host-side reference.
                        if round == 1 {
                            let votes: Vec<_> = (0..N)
                                .map(|c| {
                                    let mut crng =
                                        Rng::new(SEED ^ (c as u64) << 32 ^ 1u64);
                                    let u: Vec<f32> = (0..D)
                                        .map(|_| (crng.gaussian() * 0.01) as f32)
                                        .collect();
                                    protocol::client_vote(&u, k, SEED, 1, c)
                                })
                                .collect();
                            assert_eq!(
                                out.gia,
                                deduce_gia(&votes, 2),
                                "wire GIA diverged from host reference"
                            );
                            println!("         GIA matches the host-side reference");
                        }
                    }
                    selected_per_round.push(out.gia_indices);
                }
                retx_total.fetch_add(client.stats.retransmissions, Ordering::Relaxed);
                Ok(selected_per_round)
            }));
        }
        let mut all: Vec<Vec<Vec<usize>>> = Vec::new();
        for h in handles {
            all.push(h.join().expect("client thread panicked")?);
        }
        // Consensus is identical on every client, every round.
        for round in 0..ROUNDS {
            for c in 1..N {
                assert_eq!(all[0][round], all[c][round], "round {round} diverged");
            }
        }
        Ok(())
    })?;

    let s = handle.stats();
    println!(
        "\nserver: {} packets, {} round(s) completed, {} duplicate(s) dropped, \
         {} spilled, {} wave advance(s), {} retransmission(s) client-side",
        s.packets,
        s.rounds_completed,
        s.duplicates,
        s.spilled,
        s.waves,
        retx_total.load(Ordering::Relaxed),
    );
    handle.shutdown();
    println!("loopback round OK");
    Ok(())
}
