//! E6: the §III-B worked example, executed on the actual switch simulator.
//!
//! Two clients, a 5-parameter model, a PS that can aggregate one pair of
//! integers per operation. The paper counts:
//!   * dense aggregation      → 5 PS aggregations,
//!   * Top2 without alignment → 4 aggregations (indices unaligned),
//!   * FediAC (phase 1 + 2)   → 3 aggregations (1 vote + 2 aligned adds).
//!
//! ```bash
//! cargo run --release --example motivation
//! ```

use fediac::compress::deduce_gia;
use fediac::switch::{RegisterFile, UpdateAggregator, VoteAggregator};
use fediac::util::BitVec;

fn main() {
    let u1: Vec<i32> = vec![5, 4, 3, 2, 1];
    let u2: Vec<i32> = vec![1, 3, 4, 5, 2];
    println!("§III-B example: U1={u1:?} U2={u2:?}, PS aggregates one pair per op\n");

    // Dense: every dimension needs one aggregation.
    let dense_ops = u1.len();
    println!("dense FedAvg-on-PS: {dense_ops} aggregations");

    // Top2 without consensus: client 1 sends dims {0,1}, client 2 {2,3};
    // indices cannot be aligned, so each of the 4 updates costs an op.
    let top2_ops = 4;
    println!("Top2 (no alignment): {top2_ops} aggregations");

    // FediAC: phase 1 — each client votes its top-3 dims as a 5-bit array;
    // the vote arrays fit in one 'packet' each but aggregate in ONE op
    // because 5 bits ≤ one integer lane.
    let votes = vec![
        BitVec::from_indices(5, &[0, 1, 2]), // 11100
        BitVec::from_indices(5, &[1, 2, 3]), // 01110
    ];
    let mut rf = RegisterFile::new(64);
    let mut vote_agg = VoteAggregator::new(&mut rf, 5, 2, 2, 5).unwrap();
    for (client, v) in votes.iter().enumerate() {
        vote_agg.ingest(client, 0, &v.to_bytes());
    }
    let gia = vote_agg.gia();
    vote_agg.release(&mut rf);
    assert_eq!(gia, deduce_gia(&votes, 2), "switch and host GIA must agree");
    let selected: Vec<usize> = gia.iter_ones().collect();
    println!(
        "FediAC phase 1: votes 11100 + 01110 = 12210, threshold a=2 ⇒ GIA 01100 \
         (dims {selected:?}) — 1 aggregation"
    );

    // Phase 2: both clients upload dims {1,2}; aligned ⇒ 2 aggregations
    // (one per selected pair — the example's one-pair-per-op memory limit).
    let mut upd_agg = UpdateAggregator::new(&mut rf, selected.len(), 2, 1).unwrap();
    for (client, u) in [&u1, &u2].iter().enumerate() {
        for (block, &dim) in selected.iter().enumerate() {
            upd_agg.ingest(client, block, &[u[dim]]);
        }
    }
    assert!(upd_agg.all_complete());
    let agg: Vec<i32> = upd_agg.aggregate().to_vec();
    upd_agg.release(&mut rf);
    let phase2_ops = selected.len();
    println!(
        "FediAC phase 2: aligned uploads at dims {selected:?} sum to {agg:?} — \
         {phase2_ops} aggregations"
    );
    let fediac_ops = 1 + phase2_ops;
    println!("\nFediAC total: {fediac_ops} aggregations vs dense {dense_ops} vs Top2 {top2_ops}");
    assert_eq!(fediac_ops, 3);
    assert_eq!(agg, vec![4 + 3, 3 + 4]);
    println!("matches the paper's Fig. 1 walk-through ✓");
}
