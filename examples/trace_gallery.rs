//! Inspect the synthetic cellular traces that drive client upload rates
//! (DESIGN.md §2 substitution 2): population statistics and one trace's
//! regime structure.
//!
//! ```bash
//! cargo run --release --example trace_gallery
//! ```

use fediac::net::trace::{client_rates, CellularTrace, MAX_RATE, MIN_RATE};
use fediac::util::stats::percentile;
use fediac::util::Rng;

fn main() {
    let n = 200;
    let rates = client_rates(n, 7);
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = rates.iter().cloned().fold(0.0, f64::max);
    println!("population of {n} clients (paper range {MIN_RATE}–{MAX_RATE} pkts/s):");
    println!(
        "  min={min:.0}  p25={:.0}  median={:.0}  p75={:.0}  max={max:.0} pkts/s",
        percentile(&rates, 25.0),
        percentile(&rates, 50.0),
        percentile(&rates, 75.0)
    );

    // Histogram.
    let buckets = 10;
    let mut hist = vec![0usize; buckets];
    for &r in &rates {
        let b = (((r - MIN_RATE) / (MAX_RATE - MIN_RATE)) * buckets as f64) as usize;
        hist[b.min(buckets - 1)] += 1;
    }
    println!("\nrate histogram:");
    for (i, count) in hist.iter().enumerate() {
        let lo = MIN_RATE + (MAX_RATE - MIN_RATE) * i as f64 / buckets as f64;
        println!("  {:>5.0}+ pkts/s | {}", lo, "#".repeat(*count));
    }

    // One trace's time structure.
    let mut rng = Rng::new(3);
    let trace = CellularTrace::generate(&mut rng, 120.0, 15.0);
    println!("\none subway ride (120 s, mean {:.0} pkts/s):", trace.mean_rate());
    for t in (0..120).step_by(10) {
        let r = trace.rate_at(t as f64);
        let bar = ((r - MIN_RATE) / (MAX_RATE - MIN_RATE) * 50.0) as usize;
        println!("  t={t:>3}s {:>5.0} pkts/s | {}", r, "█".repeat(bar.max(1)));
    }
}
